"""repro-lint: JAX-aware static analysis that locks in the hot-path rules.

PRs 1-5 earned their speedups by enforcing invariants by hand — every jit
funnels through ``core.compile_cache.JitCache`` so compiles stay counted
and bounded, host syncs happen once per group instead of once per
iteration, donated buffers are never touched again, and library code never
guards correctness behind a bare ``assert`` (it vanishes under
``python -O``). Nothing checked those invariants, so any refactor could
silently regress them. This module turns them into AST-level rules:

R1  recompile hazards
    Direct ``jax.jit`` (or ``functools.partial(jax.jit, ...)``) in library
    code bypassing ``JitCache``; ``jit`` invocations inside ``for``/
    ``while`` bodies (a fresh wrapper per pass retraces every pass); and
    Python scalars (``len(x)``, ``x.shape[i]``, ``int(...)``) flowing as
    arguments into locally-jitted entry points — every distinct value
    retraces, so the value belongs in a declared bucket/compile key or in
    a traced array.

R2  host-sync points in traced context
    ``.item()``, ``int()/float()/bool()`` on non-constant values,
    ``np.asarray``/``np.array`` and ``jax.device_get`` inside functions
    reachable from ``lax.scan``/``vmap``/jitted bodies (a call-graph walk
    over the scanned tree), plus ``if`` statements on (non-static)
    parameters of directly-traced functions. Scalar conversions of
    ``.shape``/``len()`` expressions are trace-time constants and exempt.

R3  donation misuse
    A name donated to XLA (``JitCache.call`` donate tuples, immediately-
    invoked ``jax.jit(..., donate_argnums=...)``, or the engines'
    ``donate=``/``donate_params=True`` keywords) and then read later in
    the same scope — its buffer may already be reused. The check is
    linear within a statement list (no loop-back-edge analysis); a
    statement that rebinds the name clears it.

R4  dead public API / drift
    Public functions of the kernel package (``repro/kernels/*.py``) and
    the model registry (``models/registry.py``) referenced from no other
    scanned module — i.e. only from comments/docstrings or from outside
    the library. Proves (and tracks, via the baseline) the orphaned
    Pallas kernels the ROADMAP wants fused into serving.

R5  bare ``assert`` in library code
    Disabled under ``python -O`` — the exact bug class PR 5 fixed in
    ``serving._admit``. Library invariants raise ``ValueError``/
    ``RuntimeError``.

Suppression: append ``# repro-lint: disable=R1`` (comma-separate multiple
rules, or ``disable=all``) to the offending line, or put the comment alone
on the line directly above. Findings are matched against the baseline
(``tools/lint_baseline.json``) by ``(rule, path, key)`` where ``key`` is
the stripped source line (or the symbol name, for R4) — line-number-free,
so baselines survive unrelated edits and diff cleanly.

The module is dependency-free (stdlib ``ast``/``tokenize`` only): the
linter itself can never drag jax into a CI job that only wants to lint.
"""
from __future__ import annotations

import ast
import io
import json
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES = {
    "R1": "recompile hazard (jit outside JitCache / jit in loop / "
          "python scalar into jitted entry)",
    "R2": "host sync reachable from traced code",
    "R3": "donated buffer read after donation",
    "R4": "dead public API (kernel/registry orphan)",
    "R5": "bare assert in library code",
}

# Callables whose function-valued arguments are traced by JAX.
_TRACED_CALLS = {
    "jax.jit", "jax.vmap", "jax.pmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat", "jax.lax.scan", "jax.lax.map",
    "jax.lax.while_loop", "jax.lax.cond", "jax.lax.fori_loop",
    "jax.lax.switch", "jax.lax.associative_scan",
}

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*disable=((?:R\d+|all)(?:\s*,\s*(?:R\d+|all))*)")


@dataclass
class Finding:
    rule: str
    path: str          # posix path relative to the scan root's repo
    line: int
    message: str
    key: str           # line-number-free baseline key
    baselined: bool = False

    def sort_key(self):
        return (self.path, self.line, self.rule, self.key)

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message, "key": self.key,
                "baselined": self.baselined}


def baseline_key(f: Finding) -> Tuple[str, str, str]:
    return (f.rule, f.path, f.key)


# ---------------------------------------------------------------------------
# Per-module model
# ---------------------------------------------------------------------------

class _Module:
    def __init__(self, relpath: str, source: str):
        self.relpath = relpath.replace("\\", "/")
        self.source = source
        self.tree = ast.parse(source)
        self.lines = source.splitlines()
        self.imports = self._imports(self.tree)
        self.suppress = self._suppressions(source)
        # dotted module path for cross-module resolution:
        # "src/repro/core/fedavg.py" -> "repro.core.fedavg"
        p = self.relpath[:-3] if self.relpath.endswith(".py") else self.relpath
        parts = p.split("/")
        if "repro" in parts:
            parts = parts[parts.index("repro"):]
        self.modpath = ".".join(parts)
        if self.modpath.endswith(".__init__"):
            self.modpath = self.modpath[:-len(".__init__")]

    @staticmethod
    def _imports(tree) -> Dict[str, str]:
        out: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    out[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for a in node.names:
                    out[a.asname or a.name] = f"{node.module}.{a.name}"
        return out

    @staticmethod
    def _suppressions(source: str) -> Dict[int, Set[str]]:
        out: Dict[int, Set[str]] = {}
        try:
            toks = tokenize.generate_tokens(io.StringIO(source).readline)
            for tok in toks:
                if tok.type != tokenize.COMMENT:
                    continue
                m = _SUPPRESS_RE.search(tok.string)
                if m:
                    out[tok.start[0]] = {r.strip() for r in
                                         m.group(1).split(",") if r.strip()}
        except tokenize.TokenError:
            pass
        return out

    def resolve(self, node) -> Optional[str]:
        """Dotted path of a Name/Attribute chain via the import map."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        base = self.imports.get(node.id)
        if base is None:
            return None
        return ".".join([base] + parts[::-1])

    def suppressed(self, line: int, rule: str) -> bool:
        for ln in (line, line - 1):
            rs = self.suppress.get(ln)
            if not rs or not (rule in rs or "all" in rs):
                continue
            if ln == line:
                return True
            # the preceding line counts only if it is a pure comment line
            if 1 <= ln <= len(self.lines) \
                    and self.lines[ln - 1].lstrip().startswith("#"):
                return True
        return False

    def key_for(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return " ".join(self.lines[line - 1].split())
        return ""


def _jit_target(call: ast.Call, mod: _Module):
    """If ``call`` is ``jax.jit(...)`` or ``functools.partial(jax.jit,
    ...)``, return the wrapped-function node (or None); else ``False``."""
    r = mod.resolve(call.func)
    if r == "jax.jit":
        return call.args[0] if call.args else None
    if r == "functools.partial" and call.args \
            and mod.resolve(call.args[0]) == "jax.jit":
        return call.args[1] if len(call.args) > 1 else None
    return False


def _static_names(call: ast.Call) -> Set[str]:
    """static_argnames declared on a jit call (string constants only)."""
    out: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            vals = kw.value.elts if isinstance(
                kw.value, (ast.Tuple, ast.List)) else [kw.value]
            for v in vals:
                if isinstance(v, ast.Constant) and isinstance(v.value, str):
                    out.add(v.value)
    return out


# ---------------------------------------------------------------------------
# Function index + call graph (R2)
# ---------------------------------------------------------------------------

@dataclass
class _Func:
    uid: str
    node: object                      # FunctionDef / AsyncFunctionDef / Lambda
    mod: _Module
    name: str
    class_name: Optional[str]
    params: List[str] = field(default_factory=list)
    static: Set[str] = field(default_factory=set)
    nested: Dict[str, "_Func"] = field(default_factory=dict)


class _Index:
    """Project-wide function/lambda index with name-resolution helpers."""

    def __init__(self, modules: Sequence[_Module]):
        self.modules = modules
        self.funcs: Dict[str, _Func] = {}          # uid -> _Func
        self.by_node: Dict[int, _Func] = {}        # id(ast node) -> _Func
        self.top: Dict[Tuple[str, str], _Func] = {}       # (modpath, name)
        self.methods: Dict[Tuple[str, str, str], _Func] = {}
        for mod in modules:
            self._index_module(mod)

    def _index_module(self, mod: _Module):
        def visit(node, class_name, parent: Optional[_Func]):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.ClassDef):
                    visit(child, child.name, None)
                elif isinstance(child, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                    f = self._add(child, mod, child.name, class_name)
                    if parent is not None:
                        parent.nested[child.name] = f
                    elif class_name is not None:
                        self.methods[(mod.modpath, class_name,
                                      child.name)] = f
                    else:
                        self.top[(mod.modpath, child.name)] = f
                    visit(child, None, f)
                else:
                    # lambdas anywhere (call args, assignments, ...)
                    for sub in ast.walk(child):
                        if isinstance(sub, ast.Lambda):
                            self._add(sub, mod, "<lambda>", class_name)
                    visit(child, class_name, parent)
        visit(mod.tree, None, None)

    def _add(self, node, mod: _Module, name: str,
             class_name: Optional[str]) -> _Func:
        if id(node) in self.by_node:
            return self.by_node[id(node)]
        uid = f"{mod.relpath}:{name}:{node.lineno}"
        a = node.args
        params = [p.arg for p in
                  list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)]
        if a.vararg:
            params.append(a.vararg.arg)
        f = _Func(uid, node, mod, name, class_name, params)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call) \
                        and _jit_target(dec, mod) is not False:
                    f.static |= _static_names(dec)
        self.funcs[uid] = f
        self.by_node[id(node)] = f
        return f

    def resolve_callee(self, expr, mod: _Module,
                       scope: Optional[_Func]) -> Optional[_Func]:
        """Best-effort: map a callee/argument expression to an indexed
        function (nested def, module-level def, method via self, or an
        imported project function)."""
        if isinstance(expr, ast.Lambda):
            return self.by_node.get(id(expr))
        if isinstance(expr, ast.Call):            # functools.partial(f, ...)
            if mod.resolve(expr.func) == "functools.partial" and expr.args:
                return self.resolve_callee(expr.args[0], mod, scope)
            return None
        if isinstance(expr, ast.Name):
            cur = scope
            while cur is not None:
                if expr.id in cur.nested:
                    return cur.nested[expr.id]
                cur = None                        # one level is enough here
            hit = self.top.get((mod.modpath, expr.id))
            if hit is not None:
                return hit
            dotted = mod.imports.get(expr.id)
            if dotted:
                return self._by_dotted(dotted)
            return None
        if isinstance(expr, ast.Attribute):
            if isinstance(expr.value, ast.Name) and expr.value.id == "self" \
                    and scope is not None and scope.class_name:
                return self.methods.get((mod.modpath, scope.class_name,
                                         expr.attr))
            dotted = mod.resolve(expr)
            if dotted:
                return self._by_dotted(dotted)
        return None

    def _by_dotted(self, dotted: str) -> Optional[_Func]:
        if "." not in dotted:
            return None
        modpath, name = dotted.rsplit(".", 1)
        return self.top.get((modpath, name))


def _body_nodes(func: _Func):
    """AST nodes of a function body, not descending into nested function
    definitions or lambdas (those are separate indexed functions)."""
    node = func.node
    roots = node.body if isinstance(node.body, list) else [node.body]
    stack = list(roots)
    while stack:
        n = stack.pop()
        yield n
        for child in ast.iter_child_nodes(n):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            stack.append(child)


# ---------------------------------------------------------------------------
# Rule implementations
# ---------------------------------------------------------------------------

def _scalar_shaped(expr, mod: _Module) -> bool:
    """Does ``expr`` itself evaluate to a Python scalar derived from
    shapes/lengths (the classic per-value-retrace argument)?  Top-level
    structure only — a ``len()`` buried inside another call's arguments
    produces whatever that call returns, not a scalar."""
    if isinstance(expr, ast.Call) and isinstance(expr.func, ast.Name) \
            and expr.func.id in ("len", "int") \
            and expr.func.id not in mod.imports:
        return True
    if isinstance(expr, ast.Attribute) and expr.attr in ("shape", "size",
                                                         "ndim"):
        return True
    if isinstance(expr, ast.Subscript):
        return _scalar_shaped(expr.value, mod)
    if isinstance(expr, ast.BinOp):
        return (_scalar_shaped(expr.left, mod)
                or _scalar_shaped(expr.right, mod))
    if isinstance(expr, ast.UnaryOp):
        return _scalar_shaped(expr.operand, mod)
    return False


def _rule_r1(mod: _Module, findings: List[Finding]):
    if mod.relpath.endswith("core/compile_cache.py"):
        return                                   # the cache implementation
    jitted_names: Set[str] = set()
    loop_stack: List[object] = []

    def visit(node):
        is_loop = isinstance(node, (ast.For, ast.While))
        if is_loop:
            loop_stack.append(node)
        if isinstance(node, ast.Call) and _jit_target(node, mod) is not False:
            if loop_stack:
                msg = ("jax.jit inside a loop body builds a fresh wrapper "
                       "(and retraces) every pass; hoist it, or route it "
                       "through core.compile_cache.JitCache")
            else:
                msg = ("direct jax.jit bypasses core.compile_cache.JitCache"
                       " — compiles are uncounted and unbounded; route "
                       "through a JitCache (or suppress with justification)")
            findings.append(Finding("R1", mod.relpath, node.lineno, msg,
                                    mod.key_for(node.lineno)))
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                target = dec if isinstance(dec, ast.Call) else None
                if (mod.resolve(dec) == "jax.jit") or (
                        target is not None
                        and _jit_target(target, mod) is not False):
                    line = dec.lineno
                    findings.append(Finding(
                        "R1", mod.relpath, line,
                        "direct @jax.jit bypasses core.compile_cache."
                        "JitCache — compiles are uncounted and unbounded; "
                        "route through a JitCache (or suppress with "
                        "justification)", mod.key_for(line)))
                    jitted_names.add(node.name)
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call) \
                and _jit_target(node.value, mod) is not False:
            for t in node.targets:
                if isinstance(t, ast.Name):
                    jitted_names.add(t.id)
        for child in ast.iter_child_nodes(node):
            visit(child)
        if is_loop:
            loop_stack.pop()

    visit(mod.tree)

    # python scalars flowing into locally-jitted entry points
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id in jitted_names):
            continue
        for arg in node.args:
            if _scalar_shaped(arg, mod):
                findings.append(Finding(
                    "R1", mod.relpath, node.lineno,
                    f"python scalar argument to jitted "
                    f"'{node.func.id}' — every distinct value retraces; "
                    "fold it into a declared static bucket/compile key or "
                    "pass a traced array (jnp.asarray)",
                    mod.key_for(node.lineno)))
                break


def _rule_r5(mod: _Module, findings: List[Finding]):
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assert):
            findings.append(Finding(
                "R5", mod.relpath, node.lineno,
                "bare assert in library code vanishes under python -O "
                "(the serving._admit bug class); raise ValueError/"
                "RuntimeError instead", mod.key_for(node.lineno)))


def _donated_names(stmt, mod: _Module) -> List[Tuple[str, int]]:
    """(name, line) pairs donated by calls inside ``stmt``."""
    out: List[Tuple[str, int]] = []
    for call in (n for n in ast.walk(stmt) if isinstance(n, ast.Call)):
        # JitCache-style: pool.call(name, fn, (donated...), (args...))
        if isinstance(call.func, ast.Attribute) and call.func.attr == "call" \
                and len(call.args) >= 4 \
                and isinstance(call.args[2], ast.Tuple) \
                and isinstance(call.args[3], ast.Tuple):
            idxs = [c.value for c in call.args[2].elts
                    if isinstance(c, ast.Constant)
                    and isinstance(c.value, int)]
            elts = call.args[3].elts
            for i in idxs:
                if i < len(elts) and isinstance(elts[i], ast.Name):
                    out.append((elts[i].id, call.lineno))
        # immediately-invoked jax.jit(f, donate_argnums=...)(args...)
        if isinstance(call.func, ast.Call) \
                and _jit_target(call.func, mod) is not False:
            for kw in call.func.keywords:
                if kw.arg not in ("donate_argnums", "donate_argnames"):
                    continue
                vals = kw.value.elts if isinstance(
                    kw.value, (ast.Tuple, ast.List)) else [kw.value]
                idxs = [v.value for v in vals
                        if isinstance(v, ast.Constant)
                        and isinstance(v.value, int)]
                for i in idxs:
                    if i < len(call.args) \
                            and isinstance(call.args[i], ast.Name):
                        out.append((call.args[i].id, call.lineno))
        # engine keywords: donate=True donates the stack (2nd positional),
        # donate_params=True the params (1st positional).  Builders named
        # ``jit_*`` (launch.steps) take the same keywords but configure
        # donation for the function they RETURN — their own args are safe.
        term = call.func.attr if isinstance(call.func, ast.Attribute) \
            else call.func.id if isinstance(call.func, ast.Name) else ""
        if term.startswith("jit_"):
            continue
        for kw in call.keywords:
            if not (isinstance(kw.value, ast.Constant)
                    and kw.value.value is True):
                continue
            pos = {"donate": 1, "donate_params": 0}.get(kw.arg)
            if pos is not None and pos < len(call.args) \
                    and isinstance(call.args[pos], ast.Name):
                out.append((call.args[pos].id, call.lineno))
    return out


def _assigned_names(stmt) -> Set[str]:
    out: Set[str] = set()
    for n in ast.walk(stmt):
        if isinstance(n, ast.Name) and isinstance(n.ctx,
                                                  (ast.Store, ast.Del)):
            out.add(n.id)
    return out


def _rule_r3(mod: _Module, findings: List[Finding]):
    def check_body(body: List):
        live: Dict[str, int] = {}            # donated name -> donation line
        for stmt in body:
            if live:
                reads = [n for n in ast.walk(stmt)
                         if isinstance(n, ast.Name)
                         and isinstance(n.ctx, ast.Load) and n.id in live]
                for n in reads:
                    if n.id not in live:     # already reported this stmt
                        continue
                    findings.append(Finding(
                        "R3", mod.relpath, n.lineno,
                        f"'{n.id}' was donated to XLA at line "
                        f"{live[n.id]} and is read afterwards — its "
                        "buffer may already be reused; copy before "
                        "donating or drop the donation",
                        mod.key_for(n.lineno)))
                    live.pop(n.id, None)
            donated = _donated_names(stmt, mod)
            assigned = _assigned_names(stmt)
            for name, line in donated:
                if name not in assigned:     # rebinding clears the hazard
                    live[name] = line
            for name in assigned:
                live.pop(name, None)

    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            check_body(node.body)
    check_body(mod.tree.body)


def _rule_r2(modules: Sequence[_Module], index: _Index,
             findings: List[Finding]):
    roots: Dict[str, str] = {}               # uid -> why it is traced

    def mark(expr, mod, scope, why):
        f = index.resolve_callee(expr, mod, scope)
        if f is not None and f.uid not in roots:
            roots[f.uid] = why

    # decorated roots
    for f in index.funcs.values():
        node = f.node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if mod_resolves_jit(dec, f.mod):
                    roots.setdefault(f.uid, "@jax.jit")

    # functions handed to tracers — walk each indexed function's own body
    # so the enclosing scope is known (self.X / nested-def resolution)
    def scan_calls(owner: Optional[_Func], nodes, mod: _Module):
        for n in nodes:
            if not isinstance(n, ast.Call):
                continue
            r = mod.resolve(n.func)
            traced = r in _TRACED_CALLS or (r or "").endswith(".shard_map")
            if not traced and isinstance(n, ast.Call):
                t = _jit_target(n, mod)
                if t is not False and t is not None:
                    mark(t, mod, owner, "jax.jit")
                    continue
            if traced:
                for arg in list(n.args) + [kw.value for kw in n.keywords]:
                    mark(arg, mod, owner, r or "shard_map")

    for f in index.funcs.values():
        scan_calls(f, _body_nodes(f), f.mod)
    for mod in modules:
        top_nodes = []
        for stmt in mod.tree.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            top_nodes.extend(ast.walk(stmt))
        scan_calls(None, top_nodes, mod)

    # reachability over intra-project call edges
    reach: Dict[str, str] = dict(roots)
    frontier = list(roots)
    while frontier:
        uid = frontier.pop()
        f = index.funcs[uid]
        for n in _body_nodes(f):
            if not isinstance(n, ast.Call):
                continue
            callee = index.resolve_callee(n.func, f.mod, f)
            if callee is not None and callee.uid not in reach:
                reach[callee.uid] = reach[uid]
                frontier.append(callee.uid)

    # host syncs inside reachable functions
    for uid, why in sorted(reach.items()):
        f = index.funcs[uid]
        mod = f.mod
        for n in _body_nodes(f):
            sync = None
            if isinstance(n, ast.Call):
                if isinstance(n.func, ast.Attribute) and n.func.attr == \
                        "item":
                    sync = ".item()"
                elif isinstance(n.func, ast.Name) \
                        and n.func.id in ("int", "float", "bool") \
                        and n.func.id not in mod.imports and n.args \
                        and not isinstance(n.args[0], ast.Constant) \
                        and not _scalar_shaped(n.args[0], mod):
                    sync = f"{n.func.id}()"
                else:
                    r = mod.resolve(n.func)
                    if r in ("numpy.asarray", "numpy.array",
                             "jax.device_get"):
                        sync = r
            if sync:
                findings.append(Finding(
                    "R2", mod.relpath, n.lineno,
                    f"host sync {sync} inside code reachable from traced "
                    f"context ({why}) forces a device round-trip per trace"
                    " — hoist it out of the compiled body",
                    mod.key_for(n.lineno)))

    # `if` on traced (non-static) parameters of direct roots
    for uid in sorted(roots):
        f = index.funcs[uid]
        traced_params = {p for p in f.params
                         if p not in f.static and p not in ("self", "cls")}
        if not traced_params:
            continue
        for n in _body_nodes(f):
            if not isinstance(n, ast.If):
                continue
            hits = [x.id for x in ast.walk(n.test)
                    if isinstance(x, ast.Name) and x.id in traced_params]
            # exclude names only used as attribute bases (static config
            # branching like `cfg.sliding_window`)
            bases = {x.value.id for x in ast.walk(n.test)
                     if isinstance(x, ast.Attribute)
                     and isinstance(x.value, ast.Name)}
            hits = [h for h in hits if h not in bases]
            if hits:
                findings.append(Finding(
                    "R2", f.mod.relpath, n.lineno,
                    f"`if` on traced value '{hits[0]}' inside a traced "
                    f"function ({roots[uid]}) — python control flow on "
                    "tracers fails or forces a sync; use jnp.where / "
                    "lax.cond, or declare the argument static",
                    f.mod.key_for(n.lineno)))


def mod_resolves_jit(dec, mod: _Module) -> bool:
    if mod.resolve(dec) == "jax.jit":
        return True
    return isinstance(dec, ast.Call) and _jit_target(dec, mod) is not False


def _rule_r4(modules: Sequence[_Module], findings: List[Finding]):
    api_mods = [m for m in modules
                if ("/kernels/" in m.relpath
                    and not m.relpath.endswith("__init__.py"))
                or m.relpath.endswith("models/registry.py")]
    if not api_mods:
        return
    refs: Dict[str, Set[str]] = {}           # identifier -> modules using it
    for m in modules:
        for n in ast.walk(m.tree):
            name = None
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load):
                name = n.id
            elif isinstance(n, ast.Attribute):
                name = n.attr
            if name:
                refs.setdefault(name, set()).add(m.relpath)
    for m in api_mods:
        for stmt in m.tree.body:
            if not isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if stmt.name.startswith("_"):
                continue
            users = refs.get(stmt.name, set()) - {m.relpath}
            if not users:
                stem = m.relpath.rsplit("/", 1)[-1][:-3]
                findings.append(Finding(
                    "R4", m.relpath, stmt.lineno,
                    f"public '{stem}.{stmt.name}' is referenced by no other"
                    " library module (comments/docstrings/tests only) — "
                    "wire it into the hot path or track it as an open "
                    "item", key=f"{stem}.{stmt.name}"))


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def scan_sources(sources: Dict[str, str]) -> List[Finding]:
    """Lint a mapping of ``relpath -> source``. Cross-module rules (R2 call
    graph, R4 references) see exactly the modules passed in."""
    modules = []
    for relpath, src in sorted(sources.items()):
        try:
            modules.append(_Module(relpath, src))
        except SyntaxError as e:
            raise ValueError(f"{relpath}: cannot parse: {e}") from e
    findings: List[Finding] = []
    for mod in modules:
        _rule_r1(mod, findings)
        _rule_r3(mod, findings)
        _rule_r5(mod, findings)
    index = _Index(modules)
    _rule_r2(modules, index, findings)
    _rule_r4(modules, findings)
    by_mod = {m.relpath: m for m in modules}
    kept = [f for f in findings
            if not by_mod[f.path].suppressed(f.line, f.rule)]
    # identical (rule, line, key) duplicates add noise, not information
    seen: Set[Tuple] = set()
    out = []
    for f in sorted(kept, key=Finding.sort_key):
        k = (f.rule, f.path, f.line, f.key)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out


def scan_paths(root, paths: Optional[Iterable] = None) -> List[Finding]:
    """Lint ``.py`` files under ``root`` (default scope: ``src/repro``).

    ``root`` is the repo root; findings carry repo-relative posix paths.
    """
    root = Path(root)
    targets = [Path(p) for p in paths] if paths else [root / "src" / "repro"]
    sources: Dict[str, str] = {}
    for t in targets:
        t = t if t.is_absolute() else root / t
        files = sorted(t.rglob("*.py")) if t.is_dir() else [t]
        for fp in files:
            rel = fp.relative_to(root).as_posix()
            sources[rel] = fp.read_text()
    return scan_sources(sources)


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------

def load_baseline(path) -> Set[Tuple[str, str, str]]:
    p = Path(path)
    if not p.exists():
        return set()
    data = json.loads(p.read_text())
    return {(e["rule"], e["path"], e["key"]) for e in data.get("findings",
                                                              [])}


def make_baseline(findings: Sequence[Finding]) -> str:
    """Deterministic baseline JSON: sorted, deduped, repo-relative paths."""
    entries = sorted({baseline_key(f) for f in findings})
    payload = {
        "comment": "repro-lint baseline: pre-existing findings tracked but "
                   "not blocking. Regenerate with "
                   "`python tools/repro_lint.py --fix-baseline`.",
        "findings": [{"rule": r, "path": p, "key": k}
                     for (r, p, k) in entries],
    }
    return json.dumps(payload, indent=2, sort_keys=True) + "\n"


def mark_baselined(findings: Sequence[Finding],
                   baseline: Set[Tuple[str, str, str]]) -> List[Finding]:
    """Mark findings present in the baseline; return the NEW ones."""
    new = []
    for f in findings:
        f.baselined = baseline_key(f) in baseline
        if not f.baselined:
            new.append(f)
    return new
