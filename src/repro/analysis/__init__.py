"""Static analysis for the compiled hot paths (repro-lint).

``repro.analysis.lint`` is the rule engine; ``tools/repro_lint.py`` is the
CLI that runs it against the tree with the baseline in
``tools/lint_baseline.json``. See docs/static_analysis.md.
"""
from repro.analysis.lint import (Finding, RULES, scan_paths, scan_sources,
                                 load_baseline, make_baseline,
                                 mark_baselined)

__all__ = ["Finding", "RULES", "scan_paths", "scan_sources",
           "load_baseline", "make_baseline", "mark_baselined"]
