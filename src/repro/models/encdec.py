"""Encoder-decoder transformer (SeamlessM4T backbone; audio frontend is a
stub — the encoder consumes precomputed frame embeddings, per the assignment
carve-out).

Encoder: bidirectional self-attention. Decoder: causal self-attention +
cross-attention to the encoded source. Both stacks are layer-scanned.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models.attention import gqa_attention
from repro.models.common import chunked_lm_loss, fan_in_init, normal_init, \
    rms_norm
from repro.models.lm import lm_head_weight  # same tied/untied convention
from repro.types import ModelConfig


def init_params(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 12)
    d, f = cfg.d_model, cfg.d_ff
    Le, Ld = cfg.num_encoder_layers, cfg.num_layers
    init = fan_in_init()
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    enc = {
        "attn": attn_mod.init_attn_params(ks[0], cfg, Le, dtype),
        "mlp": mlp_mod.init_mlp_params(ks[1], d, f, Le, dtype),
        "ln1": jnp.zeros((Le, d), dtype),
        "ln2": jnp.zeros((Le, d), dtype),
    }
    dec = {
        "attn": attn_mod.init_attn_params(ks[2], cfg, Ld, dtype),
        "xattn": {
            "wq": init(ks[3], (Ld, d, H * hd), dtype),
            "wk": init(ks[4], (Ld, d, KV * hd), dtype),
            "wv": init(ks[5], (Ld, d, KV * hd), dtype),
            "wo": init(ks[6], (Ld, H * hd, d), dtype),
        },
        "mlp": mlp_mod.init_mlp_params(ks[7], d, f, Ld, dtype),
        "ln1": jnp.zeros((Ld, d), dtype),
        "lnx": jnp.zeros((Ld, d), dtype),
        "ln2": jnp.zeros((Ld, d), dtype),
    }
    params = {
        "embed": normal_init(0.02)(ks[8], (cfg.vocab_size, d), dtype),
        "enc_layers": enc,
        "dec_layers": dec,
        "enc_norm": jnp.zeros((d,), dtype),
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(0.02)(ks[9], (d, cfg.vocab_size),
                                              dtype)
    return params


# ---------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, src_embeds: jax.Array,
           remat: bool = True, q_chunk: int = 1024,
           act_pspec=None) -> jax.Array:
    """src_embeds: (B, S_src, d) precomputed frame embeddings (stub frontend)."""
    x = src_embeds
    S = x.shape[1]
    positions = jnp.arange(S)

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, _ = attn_mod.attn_forward(lp["attn"], h, cfg=cfg, window=0,
                                     positions=positions, causal=False,
                                     q_chunk=q_chunk)
        x = x + a
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp_mod.mlp_forward(lp["mlp"], h2, cfg.act)
        if act_pspec is not None:
            x = jax.lax.with_sharding_constraint(x, act_pspec)
        return x, None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_attn(xp, h, enc_k, enc_v, cfg, q_chunk):
    B, Sq, d = h.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", h,
                   xp["wq"].astype(h.dtype)).reshape(B, Sq, H, hd)
    out = gqa_attention(q, enc_k.astype(h.dtype), enc_v.astype(h.dtype),
                        window=0, causal=False, q_chunk=q_chunk)
    return jnp.einsum("bse,ef->bsf", out.reshape(B, Sq, H * hd),
                      xp["wo"].astype(h.dtype))


def _enc_kv(xp, enc_out, cfg):
    B, Sk, d = enc_out.shape
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    dt = enc_out.dtype
    k = jnp.einsum("bsd,de->bse", enc_out,
                   xp["wk"].astype(dt)).reshape(B, Sk, KV, hd)
    v = jnp.einsum("bsd,de->bse", enc_out,
                   xp["wv"].astype(dt)).reshape(B, Sk, KV, hd)
    return k, v


def decode_train(params, cfg: ModelConfig, tokens, enc_out,
                 remat: bool = True, q_chunk: int = 1024, act_pspec=None):
    """Teacher-forced decoder pass. Returns hidden (B, S_tgt, d)."""
    x = params["embed"][tokens].astype(enc_out.dtype)
    S = x.shape[1]
    positions = jnp.arange(S)

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, _ = attn_mod.attn_forward(lp["attn"], h, cfg=cfg, window=0,
                                     positions=positions, q_chunk=q_chunk)
        x = x + a
        hx = rms_norm(x, lp["lnx"], cfg.norm_eps)
        ek, ev = _enc_kv(lp["xattn"], enc_out, cfg)
        x = x + _cross_attn(lp["xattn"], hx, ek, ev, cfg, q_chunk)
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp_mod.mlp_forward(lp["mlp"], h2, cfg.act)
        if act_pspec is not None:
            x = jax.lax.with_sharding_constraint(x, act_pspec)
        return x, None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def logits_fn(params, cfg: ModelConfig, batch: dict, remat: bool = False,
              q_chunk: int = 1024):
    """Full decoder logits (B, S_tgt, V) — the KD/codistillation surface.

    batch: src_embeds (B, S_src, d), tokens (B, S_tgt). Unlike ``loss_fn``
    the hidden->vocab projection is not chunked: distillation consumes the
    whole logit tensor anyway.
    """
    enc_out = encode(params, cfg, batch["src_embeds"], remat=remat,
                     q_chunk=q_chunk)
    hidden = decode_train(params, cfg, batch["tokens"], enc_out,
                          remat=remat, q_chunk=q_chunk)
    head = lm_head_weight(params, cfg).astype(hidden.dtype)
    return jnp.einsum("bsd,dv->bsv", hidden, head)


def loss_fn(params, cfg: ModelConfig, batch: dict, remat: bool = True,
            q_chunk: int = 1024, loss_chunk: int = 512, dtype=None,
            act_pspec=None):
    """batch: src_embeds (B, S_src, d), tokens (B, S_tgt), labels (B, S_tgt)."""
    src = batch["src_embeds"]
    if dtype is not None:
        src = src.astype(dtype)
    enc_out = encode(params, cfg, src, remat=remat, q_chunk=q_chunk,
                     act_pspec=act_pspec)
    hidden = decode_train(params, cfg, batch["tokens"], enc_out,
                          remat=remat, q_chunk=q_chunk, act_pspec=act_pspec)
    head = lm_head_weight(params, cfg).astype(hidden.dtype)
    ce = chunked_lm_loss(hidden, head, batch["labels"], chunk=loss_chunk)
    return ce, {"ce": ce, "aux": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, src_len: int, tgt_len: int,
               dtype=jnp.bfloat16):
    Ld = cfg.num_layers
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "enc_k": jnp.zeros((Ld, batch, src_len, KV, hd), dtype),
        "enc_v": jnp.zeros((Ld, batch, src_len, KV, hd), dtype),
        "k": jnp.zeros((Ld, batch, tgt_len, KV, hd), dtype),
        "v": jnp.zeros((Ld, batch, tgt_len, KV, hd), dtype),
    }


def prefill(params, cfg: ModelConfig, src_embeds, cache,
            q_chunk: int = 1024):
    """Encode the source and precompute per-layer cross-attention K/V."""
    enc_out = encode(params, cfg, src_embeds, remat=False, q_chunk=q_chunk)

    def body(_, lp):
        k, v = _enc_kv(lp["xattn"], enc_out, cfg)
        return None, (k, v)

    _, (ek, ev) = jax.lax.scan(body, None, params["dec_layers"])
    cache = dict(cache)
    cache["enc_k"] = ek.astype(cache["enc_k"].dtype)
    cache["enc_v"] = ev.astype(cache["enc_v"].dtype)
    return cache


def decode_step(params, cfg: ModelConfig, token, cache, pos, dtype=None):
    """One target-token step. Returns (logits (B, V), cache)."""
    x = params["embed"][token][:, None, :]
    if dtype is not None:
        x = x.astype(dtype)
    positions = pos + jnp.zeros((1,), jnp.int32)

    def body(carry, xs):
        x = carry
        lp, ek, ev, ck, cv = xs
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, ac = attn_mod.attn_forward(
            lp["attn"], h, cfg=cfg, window=0, positions=positions,
            cache={"k": ck, "v": cv}, cache_index=pos, q_chunk=1)
        x = x + a
        hx = rms_norm(x, lp["lnx"], cfg.norm_eps)
        x = x + _cross_attn(lp["xattn"], hx, ek, ev, cfg, q_chunk=1)
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp_mod.mlp_forward(lp["mlp"], h2, cfg.act)
        return x, (ac["k"], ac["v"])

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["enc_k"], cache["enc_v"],
                  cache["k"], cache["v"]))
    cache = dict(cache)
    cache["k"], cache["v"] = nk, nv
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, 0, :],
                        lm_head_weight(params, cfg).astype(x.dtype))
    return logits, cache
