"""3-D ResNets (Hara et al.) — the paper's teacher/TA/student family.

NDHWC layout (channel-last, TPU-native). BasicBlock with two 3x3x3 convs and
a 1x1x1 projection shortcut on stride/width changes (paper Fig. 2). BatchNorm
is replaced by GroupNorm(32) — identical FLOP profile, no cross-device batch
stats to synchronize in the federated setting (each client's batches are tiny
and non-IID; the paper's BN stats would drift — noted in DESIGN.md).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.resnet3d import BLOCKS, CLIP_FRAMES, CLIP_SIZE
from repro.types import ModelConfig

STAGE_WIDTHS = (1, 2, 4, 8)  # multiples of the stem width


def _conv_init(key, shape, dtype=jnp.float32):
    fan_in = math.prod(shape[:-1])
    return (jax.random.normal(key, shape) / math.sqrt(fan_in)).astype(dtype)


def _blocks(cfg: ModelConfig):
    return BLOCKS[cfg.name.replace("-reduced", "")]


def init_params(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    w0 = cfg.d_model
    ks = iter(jax.random.split(key, 256))
    params: dict = {
        "stem": {"w": _conv_init(next(ks), (3, 7, 7, 3, w0), dtype),
                 "gn": jnp.ones((w0,), dtype)},
        "stages": [],
    }
    c_in = w0
    for si, nblk in enumerate(_blocks(cfg)):
        c_out = w0 * STAGE_WIDTHS[si]
        stage = []
        for bi in range(nblk):
            cin = c_in if bi == 0 else c_out
            blk = {
                "w1": _conv_init(next(ks), (3, 3, 3, cin, c_out), dtype),
                "gn1": jnp.ones((c_out,), dtype),
                "w2": _conv_init(next(ks), (3, 3, 3, c_out, c_out), dtype),
                "gn2": jnp.ones((c_out,), dtype),
            }
            if cin != c_out:
                blk["proj"] = _conv_init(next(ks), (1, 1, 1, cin, c_out),
                                         dtype)
            stage.append(blk)
        params["stages"].append(stage)
        c_in = c_out
    params["fc"] = {
        "w": _conv_init(next(ks), (c_in, cfg.num_classes), dtype),
        "b": jnp.zeros((cfg.num_classes,), dtype),
    }
    return params


def _group_norm(x, scale, groups: int = 32, eps: float = 1e-5):
    C = x.shape[-1]
    g = math.gcd(groups, C)
    shape = x.shape[:-1] + (g, C // g)
    xg = x.reshape(shape).astype(jnp.float32)
    mean = jnp.mean(xg, axis=(1, 2, 3, 5), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 3, 5), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(x.shape) * scale.astype(jnp.float32)).astype(x.dtype)


def _conv3d(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride,) * 3, padding="SAME",
        dimension_numbers=("NDHWC", "DHWIO", "NDHWC"))


def forward(params, cfg: ModelConfig, clips: jax.Array) -> jax.Array:
    """clips: (B, T, H, W, 3) -> logits (B, num_classes)."""
    x = _conv3d(clips, params["stem"]["w"], stride=2)
    x = jax.nn.relu(_group_norm(x, params["stem"]["gn"]))
    for si, stage in enumerate(params["stages"]):
        for bi, blk in enumerate(stage):
            stride = 2 if (si > 0 and bi == 0) else 1
            h = _conv3d(x, blk["w1"], stride=stride)
            h = jax.nn.relu(_group_norm(h, blk["gn1"]))
            h = _conv3d(h, blk["w2"])
            h = _group_norm(h, blk["gn2"])
            sc = x if "proj" not in blk else _conv3d(x, blk["proj"],
                                                     stride=stride)
            if stride != 1 and "proj" not in blk:
                sc = sc[:, ::stride, ::stride, ::stride, :]
            x = jax.nn.relu(h + sc)
    x = jnp.mean(x, axis=(1, 2, 3))                     # global avg pool
    return x @ params["fc"]["w"] + params["fc"]["b"]


def logits_fn(params, cfg: ModelConfig, batch: dict, **_) -> jax.Array:
    """Per-clip class logits (B, num_classes) from a batch dict — the
    KD/codistillation surface (registry.logits_fn dispatches here)."""
    return forward(params, cfg, batch["clips"])


def loss_fn(params, cfg: ModelConfig, batch: dict, **_) -> tuple:
    """batch: clips (B, T, H, W, 3), labels (B,)."""
    logits = forward(params, cfg, batch["clips"])
    from repro.models.common import cross_entropy
    ce = cross_entropy(logits, batch["labels"])
    return ce, {"ce": ce, "aux": jnp.float32(0.0)}


def param_count(cfg: ModelConfig) -> int:
    w0 = cfg.d_model
    n = 3 * 7 * 7 * 3 * w0
    c_in = w0
    for si, nblk in enumerate(_blocks(cfg)):
        c_out = w0 * STAGE_WIDTHS[si]
        for bi in range(nblk):
            cin = c_in if bi == 0 else c_out
            n += 27 * cin * c_out + 27 * c_out * c_out
            if cin != c_out:
                n += cin * c_out
        c_in = c_out
    return n + c_in * cfg.num_classes


def macs_per_clip(cfg: ModelConfig, frames: int = CLIP_FRAMES,
                  size: int = CLIP_SIZE) -> float:
    """Multiply-accumulates for one clip forward pass (convs reuse weights
    spatially — per-sample FLOPs = 2*MACs >> 2*params for CNNs)."""
    w0 = cfg.d_model
    t, hw = frames / 2, size / 2          # stem stride 2
    macs = (t * hw * hw) * 3 * 7 * 7 * 3 * w0
    c_in = w0
    for si, nblk in enumerate(_blocks(cfg)):
        c_out = w0 * STAGE_WIDTHS[si]
        if si > 0:
            t, hw = max(t / 2, 1), hw / 2
        vox = t * hw * hw
        for bi in range(nblk):
            cin = c_in if bi == 0 else c_out
            macs += vox * 27 * (cin * c_out + c_out * c_out)
            if cin != c_out:
                macs += vox * cin * c_out
        c_in = c_out
    return float(macs)


def input_shape(cfg: ModelConfig, batch: int):
    if "reduced" in cfg.name:
        return (batch, 4, 16, 16, 3)
    return (batch, CLIP_FRAMES, CLIP_SIZE, CLIP_SIZE, 3)
