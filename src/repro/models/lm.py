"""Decoder-only LM covering dense / moe / ssm / hybrid / vlm / audio-prefix.

Layer params are stacked on a leading L axis and consumed by lax.scan; the
per-layer attention window (0 = full) rides along as a scanned scalar so
heterogeneous patterns (gemma3 5:1 local:global, hymba global layers) share
one code path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import chunked_lm_loss, normal_init, rms_norm
from repro.types import ModelConfig


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def windows(cfg: ModelConfig) -> jnp.ndarray:
    return jnp.asarray([cfg.window_for_layer(i) for i in range(cfg.num_layers)],
                       jnp.int32)


def init_params(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 8)
    L, d = cfg.num_layers, cfg.d_model
    layers: dict = {
        "ln1": jnp.zeros((L, d), dtype),
    }
    if cfg.family != "ssm":
        layers["ln2"] = jnp.zeros((L, d), dtype)
    if cfg.family in ("dense", "moe", "hybrid", "vlm"):
        layers["attn"] = attn_mod.init_attn_params(ks[0], cfg, L, dtype)
    if cfg.family in ("dense", "vlm", "hybrid"):
        layers["mlp"] = mlp_mod.init_mlp_params(ks[1], d, cfg.d_ff, L, dtype)
    if cfg.family == "moe":
        layers["moe"] = moe_mod.init_moe_params(ks[2], d, cfg.d_ff, cfg.moe,
                                                L, dtype)
    if cfg.family in ("ssm", "hybrid"):
        layers["ssm"] = ssm_mod.init_ssm_params(ks[3], d, cfg.ssm, L, dtype)
    if cfg.family == "hybrid":
        layers["branch_norm_attn"] = jnp.zeros((L, d), dtype)
        layers["branch_norm_ssm"] = jnp.zeros((L, d), dtype)

    params = {
        "embed": normal_init(0.02)(ks[4], (cfg.vocab_size, d), dtype),
        "layers": layers,
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(0.02)(ks[5], (d, cfg.vocab_size),
                                              dtype)
    return params


def lm_head_weight(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


# ---------------------------------------------------------------------------
# Layer body — one code path for train / prefill / decode
# ---------------------------------------------------------------------------

def _layer(cfg: ModelConfig, lp, x, window, positions, mode: str,
           cache=None, pos=0, q_chunk: int = 1024, moe_ctx=None,
           cache_slice_window: int = 0, k_extent: int = 0, seq_lens=None,
           decode_kernel: str = "einsum"):
    """One layer. mode: 'train' | 'prefill' | 'decode'.

    Returns (x, aux_loss, new_cache).  ``seq_lens`` (B,) marks right-padded
    bucketed-prefill rows: attention needs no mask (pad keys sit at
    positions the causal mask already hides from real queries) but the SSM
    recurrence does — see ``ssm_forward``.

    The attention cache may be uniform (``{"k", "v"}`` of capacity S_max)
    or a ring buffer (``{"k_win", "v_win"}`` of capacity W, decode only —
    see ``init_ring_cache``); ``new_cache`` mirrors whichever layout came
    in. ``k_extent`` (static) bounds the K-extent a uniform-cache decode
    attends against (see ``attn_forward``).

    ``decode_kernel``: "einsum" (jnp oracle) or "pallas" (fused decode
    kernels — ring attend, extent attend, SSD step); decode mode only.
    """
    aux = jnp.float32(0.0)
    new_cache: dict = {}

    def run_ssm(h):
        if mode == "decode":
            return ssm_mod.ssm_decode_step(lp["ssm"], h, cfg.ssm,
                                           cache["ssm_state"],
                                           cache["conv_state"],
                                           kernel=decode_kernel)
        return ssm_mod.ssm_forward(lp["ssm"], h, cfg.ssm,
                                   seq_lens=seq_lens)

    def run_attn(h):
        if mode == "train":
            return attn_mod.attn_forward(lp["attn"], h, cfg=cfg,
                                         window=window, positions=positions,
                                         q_chunk=q_chunk)
        if "k_win" in cache:     # ring-buffer SWA decode
            a, (rk, rv) = attn_mod.ring_decode_attend(
                lp["attn"], h, cfg=cfg, ring_k=cache["k_win"],
                ring_v=cache["v_win"], pos=pos, window=window,
                kernel=decode_kernel)
            return a, {"k_win": rk, "v_win": rv}
        attn_cache = {"k": cache["k"], "v": cache["v"]}
        idx = 0 if mode == "prefill" else pos
        kern = decode_kernel if mode == "decode" else "einsum"
        return attn_mod.attn_forward(lp["attn"], h, cfg=cfg, window=window,
                                     positions=positions, cache=attn_cache,
                                     cache_index=idx, q_chunk=q_chunk,
                                     cache_slice_window=cache_slice_window,
                                     k_extent=k_extent, kernel=kern)

    if cfg.family == "ssm":
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        out, (st, cs) = run_ssm(h)
        if mode != "train":
            new_cache = {"ssm_state": st, "conv_state": cs}
        return x + out, aux, new_cache

    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.family == "hybrid":
        a, ac = run_attn(h)
        s, (st, cs) = run_ssm(h)
        mixed = 0.5 * (rms_norm(a, lp["branch_norm_attn"], cfg.norm_eps)
                       + rms_norm(s, lp["branch_norm_ssm"], cfg.norm_eps))
        x = x + mixed.astype(x.dtype)
        if mode != "train":
            new_cache = {**ac, "ssm_state": st, "conv_state": cs}
    else:
        a, ac = run_attn(h)
        x = x + a
        if mode != "train":
            new_cache = dict(ac)

    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = moe_mod.moe_forward(lp["moe"], h2, cfg.moe, cfg.act,
                                     moe_ctx=moe_ctx,
                                     dropless=mode != "train")
    else:
        y = mlp_mod.mlp_forward(lp["mlp"], h2, cfg.act)
    return x + y, aux, new_cache


# ---------------------------------------------------------------------------
# Forward (training / scoring)
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ModelConfig, tokens: jax.Array,
                 prefix_embeds: Optional[jax.Array] = None,
                 dtype=None) -> jax.Array:
    x = params["embed"][tokens]
    if dtype is not None:
        x = x.astype(dtype)
    if cfg.prefix_len and prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return x


def forward_hidden(params, cfg: ModelConfig, tokens: jax.Array,
                   prefix_embeds: Optional[jax.Array] = None,
                   remat: bool = True, q_chunk: int = 1024,
                   dtype=None, act_pspec=None, moe_ctx=None):
    """Returns (hidden (B, S, d), aux_loss). ``act_pspec`` optionally
    constrains the residual stream between layers (sequence parallelism —
    shrinks stored remat residuals; see launch/steps.py)."""
    x = embed_inputs(params, cfg, tokens, prefix_embeds, dtype)
    S = x.shape[1]
    positions = jnp.arange(S)
    win = windows(cfg)

    def body(carry, xs):
        x, aux = carry
        lp, w = xs
        x, a, _ = _layer(cfg, lp, x, w, positions, "train", q_chunk=q_chunk,
                         moe_ctx=moe_ctx)
        if act_pspec is not None:
            x = jax.lax.with_sharding_constraint(x, act_pspec)
        return (x, aux + a), None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                               (params["layers"], win))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def loss_fn(params, cfg: ModelConfig, batch: dict, remat: bool = True,
            q_chunk: int = 1024, loss_chunk: int = 512, dtype=None,
            act_pspec=None, moe_ctx=None):
    """Next-token CE (+ MoE aux). batch: tokens (B,S), labels (B,S)[, prefix].

    With a prefix (vlm/audio), labels cover only the token part.
    """
    hidden, aux = forward_hidden(params, cfg, batch["tokens"],
                                 batch.get("prefix_embeds"), remat=remat,
                                 q_chunk=q_chunk, dtype=dtype,
                                 act_pspec=act_pspec, moe_ctx=moe_ctx)
    if cfg.prefix_len and batch.get("prefix_embeds") is not None:
        hidden = hidden[:, cfg.prefix_len:, :]
    head = lm_head_weight(params, cfg).astype(hidden.dtype)
    ce = chunked_lm_loss(hidden, head, batch["labels"], chunk=loss_chunk)
    return ce + aux, {"ce": ce, "aux": aux}


def logits_fn(params, cfg: ModelConfig, tokens, prefix_embeds=None,
              remat: bool = False, dtype=None):
    hidden, _ = forward_hidden(params, cfg, tokens, prefix_embeds,
                               remat=remat, dtype=dtype)
    return jnp.einsum("bsd,dv->bsv", hidden,
                      lm_head_weight(params, cfg).astype(hidden.dtype))


# ---------------------------------------------------------------------------
# KV-cache serving
# ---------------------------------------------------------------------------

def swa_layer_ids(cfg: ModelConfig):
    return [i for i in range(cfg.num_layers) if cfg.window_for_layer(i) > 0]


def global_layer_ids(cfg: ModelConfig):
    return [i for i in range(cfg.num_layers) if cfg.window_for_layer(i) == 0]


def init_ring_cache(cfg: ModelConfig, batch: int, max_len: int,
                    dtype=jnp.bfloat16):
    """Decode cache with per-layer-kind sizing: full-attention layers get
    ``max_len`` buffers; SWA layers get ring buffers of their window —
    for gemma3 (5 local : 1 global, w=1024, S=32k) this is 5.1× less cache
    memory and HBM traffic than the uniform cache (beyond-paper §Perf).
    Rings are capped at ``max_len`` — positions never exceed it, so a
    window wider than the cache would only buy dead slots."""
    L = cfg.num_layers
    c: dict = {}
    if cfg.family in ("dense", "moe", "hybrid", "vlm"):
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        gl, wl = global_layer_ids(cfg), swa_layer_ids(cfg)
        if gl:
            c["k"] = jnp.zeros((len(gl), batch, max_len, kv, hd), dtype)
            c["v"] = jnp.zeros((len(gl), batch, max_len, kv, hd), dtype)
        if wl:
            W = min(cfg.sliding_window, max_len)
            c["k_win"] = jnp.zeros((len(wl), batch, W, kv, hd), dtype)
            c["v_win"] = jnp.zeros((len(wl), batch, W, kv, hd), dtype)
    if cfg.family in ("ssm", "hybrid"):
        di, nh, conv_dim = ssm_mod.dims(cfg.d_model, cfg.ssm)
        c["ssm_state"] = jnp.zeros((L, batch, nh, cfg.ssm.head_dim,
                                    cfg.ssm.d_state), dtype)
        c["conv_state"] = jnp.zeros((L, batch, cfg.ssm.d_conv - 1, conv_dim),
                                    dtype)
    return c


def ring_source_positions(last, W: int) -> jnp.ndarray:
    """Absolute position each W-ring slot holds once position ``last``
    has been written: slot ``s`` holds the latest ``p <= last`` with
    ``p ≡ s (mod W)``; negative = never written (decode masks those).
    ``last`` may be a scalar or a ``(B,)`` batch (a trailing slot axis is
    appended) — the ONE definition of the ring layout, shared by cache
    conversion, serving install, and (transposed) the decode-side mask in
    ``attention.ring_decode_attend``."""
    last = jnp.asarray(last, jnp.int32)[..., None]
    return last - jnp.mod(last - jnp.arange(W), W)


def to_ring_cache(cfg: ModelConfig, cache: dict, pos) -> dict:
    """Convert a full (uniform) cache filled up to ``pos`` exclusive into
    the ring layout (slot s of a W-ring holds the latest p ≡ s mod W)."""
    out = {}
    gl, wl = global_layer_ids(cfg), swa_layer_ids(cfg)
    if "k" in cache:
        if gl:
            idx = jnp.asarray(gl)
            out["k"] = cache["k"][idx]
            out["v"] = cache["v"][idx]
        if wl:
            W = min(cfg.sliding_window, cache["k"].shape[2])
            p_of_slot = ring_source_positions(pos - 1, W).reshape(W)
            take = jnp.clip(p_of_slot, 0, cache["k"].shape[2] - 1)
            widx = jnp.asarray(wl)
            out["k_win"] = jnp.take(cache["k"][widx], take, axis=2)
            out["v_win"] = jnp.take(cache["v"][widx], take, axis=2)
    for key in ("ssm_state", "conv_state"):
        if key in cache:
            out[key] = cache[key]
    return out


def decode_step_ring(params, cfg: ModelConfig, token, cache, pos,
                     dtype=None):
    """One decode step against a ring cache (python-unrolled layers so
    each layer's window is static). Matches decode_step numerically."""
    x = params["embed"][token][:, None, :]
    if dtype is not None:
        x = x.astype(dtype)
    positions = pos + jnp.zeros((1,), jnp.int32)
    gl, wl = global_layer_ids(cfg), swa_layer_ids(cfg)
    gmap = {layer: j for j, layer in enumerate(gl)}
    wmap = {layer: j for j, layer in enumerate(wl)}
    new_cache = dict(cache)
    for i in range(cfg.num_layers):
        lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
        w = cfg.window_for_layer(i)
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)

        def run_attn_i(h):
            if w > 0:
                a, (rk, rv) = attn_mod.ring_decode_attend(
                    lp["attn"], h, cfg=cfg, ring_k=cache["k_win"][wmap[i]],
                    ring_v=cache["v_win"][wmap[i]], pos=pos, window=w)
                return a, {"k_win": rk, "v_win": rv}
            a, ac = attn_mod.attn_forward(
                lp["attn"], h, cfg=cfg, window=jnp.int32(0),
                positions=positions,
                cache={"k": cache["k"][gmap[i]], "v": cache["v"][gmap[i]]},
                cache_index=pos, q_chunk=1)
            return a, {"k": ac["k"], "v": ac["v"]}

        if cfg.family == "ssm":
            out, (st, cs) = ssm_mod.ssm_decode_step(
                lp["ssm"], h, cfg.ssm, cache["ssm_state"][i],
                cache["conv_state"][i])
            x = x + out
            upd = {"ssm_state": st, "conv_state": cs}
        elif cfg.family == "hybrid":
            a, upd = run_attn_i(h)
            so, (st, cs) = ssm_mod.ssm_decode_step(
                lp["ssm"], h, cfg.ssm, cache["ssm_state"][i],
                cache["conv_state"][i])
            mixed = 0.5 * (rms_norm(a, lp["branch_norm_attn"], cfg.norm_eps)
                           + rms_norm(so, lp["branch_norm_ssm"],
                                      cfg.norm_eps))
            x = x + mixed.astype(x.dtype)
            upd = dict(upd)
            upd["ssm_state"] = st
            upd["conv_state"] = cs
        else:
            a, upd = run_attn_i(h)
            x = x + a
        if cfg.family != "ssm":
            h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                y, _ = moe_mod.moe_forward(lp["moe"], h2, cfg.moe, cfg.act,
                                           dropless=True)
            else:
                y = mlp_mod.mlp_forward(lp["mlp"], h2, cfg.act)
            x = x + y
        for key, val in upd.items():
            j = wmap[i] if key.endswith("_win") else \
                (gmap[i] if key in ("k", "v") else i)
            new_cache[key] = new_cache[key].at[j].set(
                val.astype(new_cache[key].dtype))
    cache = new_cache
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, 0, :],
                        lm_head_weight(params, cfg).astype(x.dtype))
    return logits, cache


def _kind_runs(cfg: ModelConfig):
    """Contiguous same-kind layer runs, in layer order:
    ``[("swa" | "full", [layer ids]), ...]``.

    ``decode_step_ring`` python-unrolls all L layers, which makes the
    decode program (and its compile) O(L).  Grouping by kind instead lets
    each run scan its layers as ONE program body — every SWA layer shares
    the static window W and every full layer the uniform cache, so within
    a run the layer stack is scan-homogeneous.  gemma3's 5:1 local:global
    pattern yields ~L/5 runs of two alternating kinds.
    """
    runs: list = []
    for i in range(cfg.num_layers):
        kind = "swa" if cfg.window_for_layer(i) > 0 else "full"
        if runs and runs[-1][0] == kind:
            runs[-1][1].append(i)
        else:
            runs.append((kind, [i]))
    return runs


def decode_step_grouped(params, cfg: ModelConfig, token, cache, pos,
                        k_ext: int = 0, dtype=None,
                        decode_kernel: str = "einsum"):
    """One decode step against an ``init_ring_cache`` layout, scanning
    contiguous same-kind layer runs (``_kind_runs``).

    SWA layers attend against their W-slot ring buffers
    (``ring_decode_attend`` — O(W) HBM per step); full-attention layers
    update their uniform cache in place and attend against its first
    ``k_ext`` positions (0 = all of them), masked at ``pos + 1`` — with
    ``k_ext >= pos + 1`` that is bit-identical to the unsliced attend,
    and O(k_ext) HBM per step.  Unlike ``decode_step_ring`` this is
    vmap/scan-friendly: the program is O(#runs), not O(L), so a serving
    batcher can vmap it over a slot batch without an L-times-unrolled
    trace.  Greedy tokens match ``decode_step`` (SWA softmax sums run in
    ring order, so floats may differ in the last ulp).

    ``decode_kernel="pallas"`` fuses every decode attend/recurrence into
    the Pallas decode kernels (see ``kernels/ops.py``) — same math, one
    HBM pass per cache.
    """
    if cfg.family == "ssm":      # no attention: ring layout == uniform
        return decode_step(params, cfg, token, cache, pos, dtype=dtype,
                           decode_kernel=decode_kernel)
    x = params["embed"][token][:, None, :]
    if dtype is not None:
        x = x.astype(dtype)
    positions = pos + jnp.zeros((1,), jnp.int32)
    wmap = {layer: j for j, layer in enumerate(swa_layer_ids(cfg))}
    gmap = {layer: j for j, layer in enumerate(global_layer_ids(cfg))}
    has_ssm = cfg.family == "hybrid"
    outs: dict = {key: [] for key in cache}
    for kind, ids in _kind_runs(cfg):
        i0, i1 = ids[0], ids[-1] + 1
        lp = jax.tree_util.tree_map(lambda a: a[i0:i1], params["layers"])
        if kind == "swa":
            j0, j1 = wmap[ids[0]], wmap[ids[-1]] + 1
            cl = {"k_win": cache["k_win"][j0:j1],
                  "v_win": cache["v_win"][j0:j1]}
            win = jnp.full((len(ids),), cfg.sliding_window, jnp.int32)
        else:
            j0, j1 = gmap[ids[0]], gmap[ids[-1]] + 1
            cl = {"k": cache["k"][j0:j1], "v": cache["v"][j0:j1]}
            win = jnp.zeros((len(ids),), jnp.int32)
        if has_ssm:
            cl["ssm_state"] = cache["ssm_state"][i0:i1]
            cl["conv_state"] = cache["conv_state"][i0:i1]

        def body(x, xs, _kind=kind):
            lp_i, w_i, cl_i = xs
            x, _, nc = _layer(cfg, lp_i, x, w_i, positions, "decode",
                              cache=cl_i, pos=pos, q_chunk=1,
                              k_extent=k_ext if _kind == "full" else 0,
                              decode_kernel=decode_kernel)
            return x, nc

        x, ncs = jax.lax.scan(body, x, (lp, win, cl))
        for key, val in ncs.items():
            outs[key].append(val.astype(cache[key].dtype))
    cache = {key: (vals[0] if len(vals) == 1 else jnp.concatenate(vals, 0))
             for key, vals in outs.items()}
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, 0, :],
                        lm_head_weight(params, cfg).astype(x.dtype))
    return logits, cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    L = cfg.num_layers
    c: dict = {}
    if cfg.family in ("dense", "moe", "hybrid", "vlm"):
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        c["k"] = jnp.zeros((L, batch, max_len, kv, hd), dtype)
        c["v"] = jnp.zeros((L, batch, max_len, kv, hd), dtype)
    if cfg.family in ("ssm", "hybrid"):
        di, nh, conv_dim = ssm_mod.dims(cfg.d_model, cfg.ssm)
        c["ssm_state"] = jnp.zeros((L, batch, nh, cfg.ssm.head_dim,
                                    cfg.ssm.d_state), dtype)
        c["conv_state"] = jnp.zeros((L, batch, cfg.ssm.d_conv - 1, conv_dim),
                                    dtype)
    return c


def _scan_cached(params, cfg, x, positions, cache, mode, pos, q_chunk,
                 seq_lens=None, decode_kernel: str = "einsum"):
    win = windows(cfg)

    def body(carry, xs):
        x, aux = carry
        lp, w, cl = xs
        x, a, nc = _layer(cfg, lp, x, w, positions, mode, cache=cl, pos=pos,
                          q_chunk=q_chunk, seq_lens=seq_lens,
                          decode_kernel=decode_kernel)
        return (x, aux + a), nc

    (x, _), new_cache = jax.lax.scan(
        body, (x, jnp.float32(0.0)), (params["layers"], win, cache))
    return x, new_cache


def prefill(params, cfg: ModelConfig, tokens, cache,
            prefix_embeds=None, q_chunk: int = 1024, dtype=None,
            lengths=None):
    """Fill the cache from position 0; returns (last_logits (B, V), cache).

    ``lengths`` (B,) int32 enables *bucketed* prefill: each row's tokens
    beyond lengths[b] are right-padding to a shared compile-friendly
    sequence length. Logits are gathered at each row's last real position,
    the SSM/conv states stop exactly there (see ``ssm_forward``), and the
    pad keys written into the KV cache are causally invisible to every
    real query and overwritten by decode before they could be attended —
    outputs are bit-identical to an unpadded per-row prefill.
    """
    x = embed_inputs(params, cfg, tokens, prefix_embeds, dtype)
    S = x.shape[1]
    seq_lens = None
    if lengths is not None:
        seq_lens = jnp.asarray(lengths, jnp.int32)
        if cfg.prefix_len and prefix_embeds is not None:
            seq_lens = seq_lens + cfg.prefix_len
    x, cache = _scan_cached(params, cfg, x, jnp.arange(S), cache,
                            "prefill", pos=0, q_chunk=q_chunk,
                            seq_lens=seq_lens)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if seq_lens is None:
        last = x[:, -1, :]
    else:
        last = jnp.take_along_axis(
            x, (seq_lens - 1)[:, None, None], axis=1)[:, 0, :]
    logits = jnp.einsum("bd,dv->bv", last,
                        lm_head_weight(params, cfg).astype(x.dtype))
    return logits, cache


def decode_step(params, cfg: ModelConfig, token, cache, pos, dtype=None,
                unroll: bool = False, window_slice: bool = False,
                decode_kernel: str = "einsum"):
    """One autoregressive step. token: (B,) int32; pos: scalar position.

    Returns (logits (B, V), new_cache).

    ``unroll=True`` python-unrolls the layer loop so each layer's window is
    STATIC, enabling ``window_slice``: SWA layers attend against a
    dynamic-slice of the last `window` cache positions — O(window) HBM
    traffic per step instead of O(S_max) (§Perf, beyond-paper).
    """
    x = params["embed"][token][:, None, :]
    if dtype is not None:
        x = x.astype(dtype)
    positions = pos + jnp.zeros((1,), jnp.int32)
    if not unroll:
        x, cache = _scan_cached(params, cfg, x, positions, cache,
                                "decode", pos=pos, q_chunk=1,
                                decode_kernel=decode_kernel)
    else:
        new_cache = dict(cache)
        for i in range(cfg.num_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            cl = {k: v[i] for k, v in cache.items()}
            w = cfg.window_for_layer(i)
            csw = w if (window_slice and w > 0) else 0
            x, _, nc = _layer(cfg, lp, x, jnp.int32(w), positions, "decode",
                              cache=cl, pos=pos, q_chunk=1,
                              cache_slice_window=csw,
                              decode_kernel=decode_kernel)
            for k, v in nc.items():
                new_cache[k] = new_cache[k].at[i].set(v.astype(
                    new_cache[k].dtype))
        cache = new_cache
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, 0, :],
                        lm_head_weight(params, cfg).astype(x.dtype))
    return logits, cache
