"""Gated MLP (SwiGLU / GeGLU / squared-ReLU-GLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activation, fan_in_init


def init_mlp_params(key, d_model: int, d_ff: int, num_layers: int,
                    dtype=jnp.float32):
    init = fan_in_init()
    ks = jax.random.split(key, 3)
    L = num_layers
    return {
        "wg": init(ks[0], (L, d_model, d_ff), dtype),
        "wi": init(ks[1], (L, d_model, d_ff), dtype),
        "wo": init(ks[2], (L, d_ff, d_model), dtype),
    }


def mlp_forward(p, x, act: str = "silu"):
    dt = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dt))
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt))
    y = activation(act)(g) * h
    return jnp.einsum("bsf,fd->bsd", y, p["wo"].astype(dt))
