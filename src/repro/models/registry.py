"""Family dispatch: one uniform API over all architectures.

    init_params(key, cfg, dtype)            -> param pytree
    loss_fn(params, cfg, batch, **kw)       -> (loss, metrics)
    init_cache(cfg, batch, shape...)        -> serving cache
    decode_step(params, cfg, token, cache, pos) -> (logits, cache)
    batch_spec(cfg, shape)                  -> jax.ShapeDtypeStruct inputs
    synth_batch(rng, cfg, shape)            -> concrete random batch (smoke)
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import encdec, lm, resnet3d
from repro.types import ModelConfig, ShapeConfig

LM_FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm")
ENCDEC_FAMILIES = ("encdec", "audio")

# Decoder-side target length used by enc-dec serving shapes: the assigned
# seq_len measures the *source*; the decoder cache is bounded separately.
ENCDEC_TGT_LEN = 1024


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    if cfg.family in LM_FAMILIES:
        return lm.init_params(key, cfg, dtype)
    if cfg.family in ENCDEC_FAMILIES:
        return encdec.init_params(key, cfg, dtype)
    if cfg.family == "resnet3d":
        return resnet3d.init_params(key, cfg, dtype)
    raise ValueError(cfg.family)


def loss_fn(params, cfg: ModelConfig, batch: dict, **kw):
    if cfg.family in LM_FAMILIES:
        return lm.loss_fn(params, cfg, batch, **kw)
    if cfg.family in ENCDEC_FAMILIES:
        return encdec.loss_fn(params, cfg, batch, **kw)
    if cfg.family == "resnet3d":
        return resnet3d.loss_fn(params, cfg, batch, **kw)
    raise ValueError(cfg.family)


def logits_fn(params, cfg: ModelConfig, batch: dict, **kw):
    """Full logits (KD needs them). LM: (B,S,V); resnet: (B, classes).

    Each family module owns its logits composition; this dispatches.
    """
    if cfg.family in LM_FAMILIES:
        return lm.logits_fn(params, cfg, batch["tokens"],
                            batch.get("prefix_embeds"), **kw)
    if cfg.family in ENCDEC_FAMILIES:
        return encdec.logits_fn(params, cfg, batch, **kw)
    if cfg.family == "resnet3d":
        return resnet3d.logits_fn(params, cfg, batch, **kw)
    raise ValueError(cfg.family)


def logit_width(cfg: ModelConfig) -> int:
    """Width of the last logits axis — the KD compatibility contract: a
    teacher and student can only distill if their widths match."""
    return cfg.num_classes if cfg.family == "resnet3d" else cfg.vocab_size


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16):
    if cfg.family in LM_FAMILIES:
        return lm.init_cache(cfg, batch, seq_len, dtype)
    if cfg.family in ENCDEC_FAMILIES:
        return encdec.init_cache(cfg, batch, seq_len, ENCDEC_TGT_LEN, dtype)
    raise ValueError(f"{cfg.family}: no autoregressive cache")


def decode_step(params, cfg: ModelConfig, token, cache, pos, **kw):
    if cfg.family in LM_FAMILIES:
        return lm.decode_step(params, cfg, token, cache, pos, **kw)
    if cfg.family in ENCDEC_FAMILIES:
        return encdec.decode_step(params, cfg, token, cache, pos, **kw)
    raise ValueError(cfg.family)


def init_ring_cache(cfg: ModelConfig, batch: int, seq_len: int,
                    dtype=jnp.bfloat16):
    """Per-layer-kind decode cache: W-slot ring buffers for SWA layers,
    ``seq_len`` buffers for full-attention layers (LM families only)."""
    if cfg.family in LM_FAMILIES:
        return lm.init_ring_cache(cfg, batch, seq_len, dtype)
    raise ValueError(f"{cfg.family}: no ring decode cache")


def decode_step_grouped(params, cfg: ModelConfig, token, cache, pos, **kw):
    """Scan-grouped decode against an ``init_ring_cache`` layout; ``k_ext``
    (static) bounds the K-extent full-attention layers attend against."""
    if cfg.family in LM_FAMILIES:
        return lm.decode_step_grouped(params, cfg, token, cache, pos, **kw)
    raise ValueError(f"{cfg.family}: no grouped ring decode")


def prefill(params, cfg: ModelConfig, batch: dict, cache, **kw):
    if cfg.family in LM_FAMILIES:
        return lm.prefill(params, cfg, batch["tokens"], cache,
                          batch.get("prefix_embeds"), **kw)
    if cfg.family in ENCDEC_FAMILIES:
        if kw.pop("lengths", None) is not None:
            raise ValueError(
                f"{cfg.family}: bucketed prefill (lengths=) is LM-only")
        return encdec.prefill(params, cfg, batch["src_embeds"], cache, **kw)
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Input specs / synthetic batches
# ---------------------------------------------------------------------------

def batch_spec(cfg: ModelConfig, shape: ShapeConfig, act_dtype=jnp.bfloat16):
    """ShapeDtypeStructs for a *training/prefill* batch (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "resnet3d":
        ishape = resnet3d.input_shape(cfg, B)
        return {"clips": jax.ShapeDtypeStruct(ishape, act_dtype),
                "labels": jax.ShapeDtypeStruct((B,), jnp.int32)}
    if cfg.family in ENCDEC_FAMILIES:
        tgt = S // 2 if shape.kind == "train" else ENCDEC_TGT_LEN
        src = S - tgt if shape.kind == "train" else S
        return {
            "src_embeds": jax.ShapeDtypeStruct((B, src, cfg.d_model),
                                               act_dtype),
            "tokens": jax.ShapeDtypeStruct((B, tgt), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, tgt), jnp.int32),
        }
    spec = {}
    text = S
    if cfg.prefix_len:
        text = S - cfg.prefix_len
        spec["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.prefix_len, cfg.d_model), act_dtype)
    spec["tokens"] = jax.ShapeDtypeStruct((B, text), jnp.int32)
    spec["labels"] = jax.ShapeDtypeStruct((B, text), jnp.int32)
    return spec


def decode_spec(cfg: ModelConfig, shape: ShapeConfig, cache_dtype=jnp.bfloat16):
    """ShapeDtypeStructs for one serve_step: (token, cache, pos)."""
    B, S = shape.global_batch, shape.seq_len
    token = jax.ShapeDtypeStruct((B,), jnp.int32)
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, S, cache_dtype))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return token, cache, pos


def synth_batch(rng: np.random.Generator, cfg: ModelConfig,
                shape: ShapeConfig, act_dtype=jnp.float32):
    """Concrete random batch matching batch_spec (for smoke tests)."""
    spec = batch_spec(cfg, shape, act_dtype)
    out = {}
    for k, s in spec.items():
        if s.dtype == jnp.int32:
            hi = logit_width(cfg)
            out[k] = jnp.asarray(rng.integers(0, hi, s.shape, dtype=np.int32))
        else:
            out[k] = jnp.asarray(
                rng.standard_normal(s.shape, dtype=np.float32)).astype(s.dtype)
    return out
