"""GQA attention with dynamic sliding windows and KV-cache decode.

The same code path serves full attention (window == 0) and sliding-window
attention (window > 0) so a scanned layer stack can carry a per-layer window
scalar. Prefill uses query chunking (exact row softmax against full K) to
bound the score tensor at (B, H, q_chunk, S_k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.common import apply_rope

NEG_INF = -1e30


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, window,
               causal: bool) -> jax.Array:
    """(S_q, S_k) additive bias. window: 0/scalar -> full when 0."""
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    ok = (dq >= dk) if causal else jnp.ones((q_pos.shape[0], k_pos.shape[0]),
                                            bool)
    w = jnp.asarray(window, jnp.int32)
    big = jnp.int32(2**30)
    w_eff = jnp.where(w == 0, big, w)
    ok = ok & (dq - dk < w_eff) & (dk >= 0)   # dk<0 = unwritten ring slot
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  window=0, causal: bool = True,
                  q_offset: jax.Array | int = 0,
                  k_offset: jax.Array | int = 0,
                  k_positions: jax.Array | None = None,
                  k_len: jax.Array | None = None,
                  q_chunk: int = 1024,
                  kernel: str = "einsum") -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D) -> (B, Sq, H, D).

    ``q_offset``/``k_offset`` are the absolute positions of q[0]/k[0]
    (decode against a full or window-sliced cache). ``k_positions``
    overrides them with an arbitrary per-slot position vector (ring-buffer
    caches; negative = unwritten slot, always masked). ``k_len`` masks
    absolute cache positions >= k_len (pre-allocated cache).

    ``kernel="pallas"`` routes the no-cache causal self-attend
    (training/scoring: Sq == Sk, no offsets/positions/k_len) through the
    flash SWA kernel (``kernels.ops.swa_attention``); requires a static
    int ``window``. Everything else uses the einsum path.
    """
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    if kernel == "pallas":
        if (k_positions is not None or k_len is not None or not causal
                or Sq != Sk or not isinstance(window, int)):
            raise ValueError(
                "kernel='pallas' supports the causal self-attend only "
                "(Sq == Sk, static int window, no k_positions/k_len)")
        kg = jnp.repeat(k, G, axis=2) if G > 1 else k   # (B, Sk, H, D)
        vg = jnp.repeat(v, G, axis=2) if G > 1 else v
        fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
        out = ops.swa_attention(fold(q), fold(kg), fold(vg), window)
        return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
    elif kernel != "einsum":
        raise ValueError(f"unknown attention kernel {kernel!r}")
    scale = D ** -0.5
    qg = q.reshape(B, Sq, KV, G, D)
    k_pos = k_positions if k_positions is not None \
        else k_offset + jnp.arange(Sk)

    def attend(q_blk, q_pos):
        # q_blk: (B, C, KV, G, D). bf16 operands, f32 accumulation (MXU).
        # named_scope lets the roofline analyzer attribute the materialized
        # score/probability tensors — the buffers the Pallas flash kernel
        # (kernels/swa_attention.py) keeps in VMEM on TPU.
        with jax.named_scope("attn_inner"):
            s = jnp.einsum("bckgd,bskd->bckgs", q_blk, k,
                           preferred_element_type=jnp.float32) * scale
            bias = _mask_bias(q_pos, k_pos, window, causal)      # (C, Sk)
            if k_len is not None:
                bias = bias + jnp.where(k_pos[None, :] < k_len, 0.0, NEG_INF)
            s = s + bias[None, :, None, None, :]
            p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
            return jnp.einsum("bckgs,bskd->bckgd", p, v,
                              preferred_element_type=jnp.float32
                              ).astype(q.dtype)

    if Sq <= q_chunk:
        out = attend(qg, q_offset + jnp.arange(Sq))
    else:
        if Sq % q_chunk != 0:
            raise ValueError(
                f"seq len {Sq} not divisible by q_chunk {q_chunk}")
        n = Sq // q_chunk
        qs = qg.reshape(B, n, q_chunk, KV, G, D).transpose(1, 0, 2, 3, 4, 5)
        offs = q_offset + jnp.arange(n) * q_chunk

        def body(_, xs):
            q_blk, off = xs
            return None, attend(q_blk, off + jnp.arange(q_chunk))

        _, outs = jax.lax.scan(body, None, (qs, offs))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KV, G, D)
    return out.reshape(B, Sq, H, D)


# ---------------------------------------------------------------------------
# Full attention layer (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------

def init_attn_params(key, cfg, num_layers: int, dtype=jnp.float32):
    from repro.models.common import fan_in_init
    init = fan_in_init()
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    L = num_layers
    return {
        "wq": init(ks[0], (L, d, H * hd), dtype),
        "wk": init(ks[1], (L, d, KV * hd), dtype),
        "wv": init(ks[2], (L, d, KV * hd), dtype),
        "wo": init(ks[3], (L, H * hd, d), dtype),
    }


def ring_decode_attend(p, x, *, cfg, ring_k, ring_v, pos, window: int,
                       kernel: str = "einsum"):
    """Decode attention against a ring-buffer cache of size ``window``.

    ring_k/v: (B, W, KV, D) with slot s holding the latest position
    p ≡ s (mod W); the new k/v are written at slot pos % W. Returns
    (out, (ring_k, ring_v)). O(window) HBM per step regardless of context.

    ``kernel="pallas"`` runs the attend as the fused ring kernel
    (``kernels.ops.ring_decode_attend``) — the slot->position mapping and
    window mask happen inside the kernel, one HBM pass over the W slots.
    Requires Sq == 1 (decode).
    """
    B, Sq, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype
    W = ring_k.shape[1]
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt)).reshape(B, Sq, H, hd)
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(dt)).reshape(B, Sq, KV, hd)
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(dt)).reshape(B, Sq, KV, hd)
    q = apply_rope(q, positions_like(pos), cfg.rope_theta)
    k = apply_rope(k, positions_like(pos), cfg.rope_theta)
    slot = jnp.mod(pos, W)
    ring_k = jax.lax.dynamic_update_slice_in_dim(
        ring_k, k.astype(ring_k.dtype), slot, axis=1)
    ring_v = jax.lax.dynamic_update_slice_in_dim(
        ring_v, v.astype(ring_v.dtype), slot, axis=1)
    if kernel == "pallas":
        if Sq != 1:
            raise ValueError(f"kernel='pallas' requires Sq == 1, got {Sq}")
        qr = q[:, 0].reshape(B, KV, H // KV, hd)
        o = ops.ring_decode_attend(qr, ring_k, ring_v, pos, window)
        out = o.reshape(B, Sq, H, hd)
    elif kernel == "einsum":
        # absolute position per slot (negative = not yet written -> masked)
        s_idx = jnp.arange(W)
        k_pos = pos - jnp.mod(pos - s_idx, W)
        out = gqa_attention(q, ring_k, ring_v, window=window, causal=True,
                            q_offset=pos, k_positions=k_pos, q_chunk=1)
    else:
        raise ValueError(f"unknown decode kernel {kernel!r}")
    out = jnp.einsum("bse,ef->bsf", out.reshape(B, Sq, H * hd),
                     p["wo"].astype(dt))
    return out, (ring_k, ring_v)


def positions_like(pos):
    return pos + jnp.zeros((1,), jnp.int32)


def attn_forward(p, x, *, cfg, window, positions, causal=True,
                 cache=None, cache_index=None, q_chunk=1024,
                 cache_slice_window: int = 0, k_extent: int = 0,
                 kernel: str = "einsum"):
    """One attention layer (params already per-layer, no leading L).

    cache: optional dict {"k": (B, S_max, KV, D), "v": ...} updated at
    ``cache_index`` (decode/prefill-into-cache). Returns (out, new_cache).

    ``cache_slice_window`` (static, decode only): attend against a
    dynamic-slice of the cache covering the last ``window`` positions
    instead of the whole buffer — SWA layers then read O(window) HBM per
    step instead of O(S_max) (§Perf optimization, beyond-paper).

    ``k_extent`` (static, decode only): attend against the first
    ``k_extent`` cache positions instead of all S_max — full-attention
    layers then read O(active prefix) HBM per step. The cache itself
    stays S_max (the update is in place); only the attend is sliced.
    Requires ``k_extent >= cache_index + Sq`` and is then bit-identical
    to the unsliced attend: the dropped positions are exactly the ones
    the ``k_len`` mask already zeroes.

    ``kernel="pallas"`` (decode only: Sq == 1, cache present, no
    ``cache_slice_window``) runs the attend as the fused ladder-bucketed
    extent kernel (``kernels.ops.extent_decode_attend``): the static
    ``k_extent`` bounds the HBM read via the BlockSpec and the causal
    ``k_len`` mask is applied inside the kernel.
    """
    B, Sq, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt)).reshape(B, Sq, H, hd)
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(dt)).reshape(B, Sq, KV, hd)
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(dt)).reshape(B, Sq, KV, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = gqa_attention(q, k, v, window=window, causal=causal,
                            q_chunk=q_chunk)
        new_cache = None
    else:
        idx = cache_index if cache_index is not None else 0
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(
            cache["k"].dtype), idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(
            cache["v"].dtype), idx, axis=1)
        w_slice = cache_slice_window
        if kernel == "pallas":
            if Sq != 1 or w_slice:
                raise ValueError(
                    "kernel='pallas' requires decode (Sq == 1) without "
                    "cache_slice_window")
            S_max = ck.shape[1]
            ext = k_extent if (k_extent and k_extent < S_max) else S_max
            qr = q[:, 0].reshape(B, KV, H // KV, hd)
            o = ops.extent_decode_attend(qr, ck, cv, idx, window, ext)
            out = o.reshape(B, Sq, H, hd)
        elif kernel != "einsum":
            raise ValueError(f"unknown decode kernel {kernel!r}")
        elif w_slice and w_slice < ck.shape[1]:
            start = jnp.clip(idx + Sq - w_slice, 0, ck.shape[1] - w_slice)
            ks = jax.lax.dynamic_slice_in_dim(ck, start, w_slice, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(cv, start, w_slice, axis=1)
            out = gqa_attention(q, ks, vs, window=window, causal=causal,
                                q_offset=idx, k_offset=start,
                                k_len=idx + Sq, q_chunk=q_chunk)
        elif k_extent and k_extent < ck.shape[1]:
            ks = jax.lax.slice_in_dim(ck, 0, k_extent, axis=1)
            vs = jax.lax.slice_in_dim(cv, 0, k_extent, axis=1)
            out = gqa_attention(q, ks, vs, window=window, causal=causal,
                                q_offset=idx, k_len=idx + Sq, q_chunk=q_chunk)
        else:
            out = gqa_attention(q, ck, cv, window=window, causal=causal,
                                q_offset=idx, k_len=idx + Sq, q_chunk=q_chunk)
        new_cache = {"k": ck, "v": cv}
    out = jnp.einsum("bse,ef->bsf", out.reshape(B, Sq, H * hd),
                     p["wo"].astype(dt))
    return out, new_cache
