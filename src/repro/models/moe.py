"""Token-choice top-k MoE with capacity.

Two dispatch paths:

- **Local (single host / tests)**: scatter/gather into an (E, C, d) buffer.
- **Distributed (`moe_ctx` given)**: the dispatch and combine run inside
  ``shard_map`` (the version-portable wrapper in ``sharding.specs``) over
  the data axes — each data shard routes its local
  tokens into a *local* capacity slice (E, C_loc, d), the shards concatenate
  into the global (E, C, d) buffer along the capacity dim, and the expert
  matmuls run under pjit with expert weights sharded over 'model'
  (expert-parallel) or 2-D (d×'data', f×'model') when E doesn't divide the
  axis. GSPMD cannot shard a scatter whose indexed dim is partitioned —
  without shard_map the dispatch buffer materializes at *global* capacity
  per device (60 GiB for grok-1 train_4k), which is why this path exists.

``moe_ctx = {"mesh": Mesh, "dp": axis-or-tuple}`` is threaded from
launch/steps.py through loss_fn.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import activation, fan_in_init
from repro.sharding.specs import shard_map
from repro.types import MoEConfig


def init_moe_params(key, d_model: int, d_ff: int, moe: MoEConfig,
                    num_layers: int, dtype=jnp.float32):
    init = fan_in_init()
    ks = jax.random.split(key, 7)
    L, E = num_layers, moe.num_experts
    p = {
        "router": init(ks[0], (L, d_model, E), dtype),
        "wg": init(ks[1], (L, E, d_model, d_ff), dtype),
        "wi": init(ks[2], (L, E, d_model, d_ff), dtype),
        "wo": init(ks[3], (L, E, d_ff, d_model), dtype),
    }
    if moe.shared_expert:
        p["shared_wg"] = init(ks[4], (L, d_model, d_ff), dtype)
        p["shared_wi"] = init(ks[5], (L, d_model, d_ff), dtype)
        p["shared_wo"] = init(ks[6], (L, d_ff, d_model), dtype)
    return p


def capacity(num_tokens: int, moe: MoEConfig) -> int:
    # pure python shape math on the (static) token count: C is a compile-
    # time constant inside the traced dispatch, not a device sync.
    # repro-lint: disable=R2
    return int(math.ceil(num_tokens / moe.num_experts
                         * moe.capacity_factor * moe.top_k))


def _route(router_w, xt, moe: MoEConfig, C: int):
    """Local routing: returns (weights (T,k), slot (T*k,), keep (T*k,),
    frac (E,), mean_p (E,))."""
    E, k = moe.num_experts, moe.top_k
    T = xt.shape[0]
    logits = jnp.einsum("td,de->te", xt,
                        router_w.astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, expert_idx = jax.lax.top_k(probs, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    e_flat = expert_idx.reshape(T * k)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
    pos = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1
    keep = pos < C
    slot = jnp.where(keep, e_flat * C + jnp.minimum(pos, C - 1), E * C)
    frac = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32),
                    axis=0)
    mean_p = jnp.mean(probs, axis=0)
    return weights, slot, keep, frac, mean_p


def _dispatch(x_rep, slot, E, C):
    """(T*k, d) token copies -> (E, C, d) buffer (extra row = drop bin)."""
    d = x_rep.shape[-1]
    buf = jnp.zeros((E * C + 1, d), x_rep.dtype).at[slot].set(x_rep)
    return buf[: E * C].reshape(E, C, d)


def _combine(out_e, slot, keep, weights, T, k):
    d = out_e.shape[-1]
    out_pad = jnp.concatenate(
        [out_e.reshape(-1, d), jnp.zeros((1, d), out_e.dtype)], 0)
    g = out_pad[slot] * keep[:, None].astype(out_e.dtype)
    return jnp.sum(g.reshape(T, k, d)
                   * weights.reshape(T, k, 1).astype(out_e.dtype), axis=1)


def _expert_ffn(p, eb, act):
    dt = eb.dtype
    g = jnp.einsum("ecd,edf->ecf", eb, p["wg"].astype(dt))
    h = jnp.einsum("ecd,edf->ecf", eb, p["wi"].astype(dt))
    y = activation(act)(g) * h
    return jnp.einsum("ecf,efd->ecd", y, p["wo"].astype(dt))


def _pmean(v, names):
    for n in (names if isinstance(names, tuple) else (names,)):
        v = jax.lax.pmean(v, n)
    return v


def moe_forward(p, x, moe: MoEConfig, act: str = "silu", moe_ctx=None,
                dropless: bool = False):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    ``dropless=True`` (inference: prefill/decode) sizes capacity at C = T
    so no token is ever dropped: top_k picks *distinct* experts per token,
    so an expert holds at most T assignments. Routing then has no
    cross-token interaction at all — each token's output depends only on
    its own router logits — which is what makes batched/bucketed serving
    prefill bit-identical to single-request runs (docs/serving.md).
    Capacity dropping stays a train-time load-balancing concern.
    """
    B, S, d = x.shape
    T = B * S
    E, k = moe.num_experts, moe.top_k
    xt = x.reshape(T, d)

    if moe_ctx is None:
        # ---- local path (tests / single host) ----
        C = T if dropless else capacity(T, moe)
        weights, slot, keep, frac, mean_p = _route(p["router"], xt, moe, C)
        x_rep = jnp.repeat(xt, k, axis=0)
        eb = _dispatch(x_rep, slot, E, C)
        out_e = _expert_ffn(p, eb, act)
        out = _combine(out_e, slot, keep, weights, T, k)
    else:
        # ---- distributed path: per-data-shard dispatch, pjit expert FFN ----
        mesh, dp = moe_ctx["mesh"], moe_ctx["dp"]

        def disp(router_w, xt_loc):
            T_loc = xt_loc.shape[0]
            C_loc = capacity(T_loc, moe)
            weights, slot, keep, frac, mean_p = _route(router_w, xt_loc,
                                                       moe, C_loc)
            x_rep = jnp.repeat(xt_loc, k, axis=0)
            eb = _dispatch(x_rep, slot, E, C_loc)
            return eb, weights, slot, keep, _pmean(frac, dp), \
                _pmean(mean_p, dp)

        eb, weights, slot, keep, frac, mean_p = shard_map(
            disp, mesh=mesh,
            in_specs=(P(None, None), P(dp, None)),
            out_specs=(P(None, dp, None), P(dp, None), P(dp), P(dp),
                       P(), P()),
            check_replication=False,
        )(p["router"], xt)

        out_e = _expert_ffn(p, eb, act)

        def comb(out_loc, weights, slot, keep):
            T_loc = weights.shape[0]
            return _combine(out_loc, slot, keep, weights, T_loc, k)

        out = shard_map(
            comb, mesh=mesh,
            in_specs=(P(None, dp, None), P(dp, None), P(dp), P(dp)),
            out_specs=P(dp, None),
            check_replication=False,
        )(out_e, weights, slot, keep)

    out = out.reshape(B, S, d)
    if moe.shared_expert:
        dt = x.dtype
        g = jnp.einsum("bsd,df->bsf", x, p["shared_wg"].astype(dt))
        hh = jnp.einsum("bsd,df->bsf", x, p["shared_wi"].astype(dt))
        out = out + jnp.einsum("bsf,fd->bsd", activation(act)(g) * hh,
                               p["shared_wo"].astype(dt))

    # switch-style load-balance aux loss
    aux = E * jnp.sum(frac * mean_p) * moe.router_aux_weight
    return out, aux
