"""Shared building blocks: norms, RoPE, activations, initializers.

All models are pure functions over param pytrees (dicts of jnp arrays).
Layer-stacked params carry a leading ``L`` axis and are consumed by
``jax.lax.scan`` so compiled HLO size is independent of depth.
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

Initializer = Callable[[jax.Array, tuple, jnp.dtype], jax.Array]


def normal_init(stddev: float = 0.02):
    def init(key, shape, dtype=jnp.float32):
        return (stddev * jax.random.normal(key, shape)).astype(dtype)
    return init


def fan_in_init():
    def init(key, shape, dtype=jnp.float32):
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = 1.0 / math.sqrt(fan_in)
        return (std * jax.random.normal(key, shape)).astype(dtype)
    return init


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu":
        # squared relu (Nemotron/minitron); plain relu is never used gated
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate pairs. x: (..., S, H, D); positions: broadcastable to (..., S)."""
    freqs = rope_freqs(x.shape[-1], theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                          # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_index: int = -100) -> jax.Array:
    """Mean CE over non-ignored positions. logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels != ignore_index).astype(jnp.float32)
    nll = (lse - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_lm_loss(hidden: jax.Array, lm_head: jax.Array, labels: jax.Array,
                    chunk: int = 512) -> jax.Array:
    """Next-token CE without materializing (B, S, V) at once.

    Scans over sequence chunks; each chunk's logits are rematerialized in the
    backward pass (jax.checkpoint), so peak memory is (B, chunk, V).
    hidden: (B, S, d); lm_head: (d, V); labels: (B, S).
    """
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    if S % chunk:
        pad = chunk - S % chunk
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
        S += pad
    n = S // chunk
    hid = hidden.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    lab = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def one(h, l):
        logits = jnp.einsum("bsd,dv->bsv", h, lm_head)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(l, 0)[..., None], axis=-1)[..., 0]
        mask = (l != -100).astype(jnp.float32)
        return jnp.sum((lse - gold) * mask), jnp.sum(mask)

    def body(carry, xs):
        h, l = xs
        nll, cnt = one(h, l)
        return (carry[0] + nll, carry[1] + cnt), None

    (nll, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (hid, lab))
    return nll / jnp.maximum(cnt, 1.0)
