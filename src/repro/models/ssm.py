"""Mamba2 SSD (state-space duality) blocks — chunked scan, pure jnp.

This is also the oracle (`ref`) the Pallas ssd_scan kernel is validated
against. Group count G=1 (B/C shared across heads), as in Mamba2-130m.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.common import fan_in_init, rms_norm
from repro.types import SSMConfig


def dims(d_model: int, ssm: SSMConfig):
    d_inner = ssm.expand * d_model
    n_heads = d_inner // ssm.head_dim
    conv_dim = d_inner + 2 * ssm.d_state     # x, B, C go through the conv
    return d_inner, n_heads, conv_dim


def init_ssm_params(key, d_model: int, ssm: SSMConfig, num_layers: int,
                    dtype=jnp.float32):
    init = fan_in_init()
    di, nh, conv_dim = dims(d_model, ssm)
    ks = jax.random.split(key, 5)
    L = num_layers
    proj_out = 2 * di + 2 * ssm.d_state + nh      # z, x, B, C, dt
    return {
        "in_proj": init(ks[0], (L, d_model, proj_out), dtype),
        "conv_w": init(ks[1], (L, ssm.d_conv, conv_dim), dtype),
        "conv_b": jnp.zeros((L, conv_dim), dtype),
        "A_log": jnp.zeros((L, nh), dtype),       # A = -exp(A_log) = -1 init
        "D": jnp.ones((L, nh), dtype),
        "dt_bias": jnp.zeros((L, nh), dtype),
        "norm": jnp.zeros((L, di), dtype),
        "out_proj": init(ks[4], (L, di, d_model), dtype),
    }


def _segsum(x):
    """x: (..., Q) -> (..., Q, Q) lower-tri cumulative sums sum_{j<i<=k}."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, h0=None):
    """SSD forward. Returns (y, final_state).

    xh: (B, S, H, P) inputs per head
    dt: (B, S, H)    positive step sizes (already softplus'ed)
    A:  (H,)         negative decay rates
    Bm, Cm: (B, S, N) state in/out projections (G=1, shared over heads)
    h0: optional initial state (B, H, P, N)
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:
        # zero-pad to a chunk multiple: dt=0 rows are exact no-ops
        # (decay exp(0)=1, contribution dt·x⊗B = 0)
        pad = Q - S % Q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S += pad
    nc = S // Q

    x = xh.reshape(Bsz, nc, Q, H, P)
    dt_c = dt.reshape(Bsz, nc, Q, H)
    B_c = Bm.reshape(Bsz, nc, Q, N)
    C_c = Cm.reshape(Bsz, nc, Q, N)

    dA = dt_c * A[None, None, None, :]                  # (b,c,q,h) negative
    cum = jnp.cumsum(dA, axis=2)                        # within-chunk cumsum

    # --- intra-chunk (quadratic within chunk) ---
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))      # (b,c,h,q,k)
    scores = jnp.einsum("bcqn,bckn->bcqk", C_c, B_c)    # (b,c,q,k)
    xdt = x * dt_c[..., None]                           # fold dt into x
    y = jnp.einsum("bchqk,bcqk,bckhp->bcqhp", L, scores, xdt)

    # --- chunk states ---
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)     # (b,c,q,h)
    states = jnp.einsum("bcqh,bcqn,bcqhp->bchpn",
                        dt_c * decay_states, B_c, x)
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))          # (b,c,h)

    # --- inter-chunk recurrence ---
    init = h0 if h0 is not None else jnp.zeros((Bsz, H, P, N), x.dtype)

    def body(h, xs):
        st, dec = xs                                    # (b,h,p,n), (b,h)
        h_out = h                                       # state entering chunk
        h = h * dec[..., None, None] + st
        return h, h_out

    sts = states.transpose(1, 0, 2, 3, 4)               # (c,b,h,p,n)
    decs = chunk_decay.transpose(1, 0, 2)               # (c,b,h)
    h_final, h_prev = jax.lax.scan(body, init.astype(jnp.float32),
                                   (sts.astype(jnp.float32),
                                    decs.astype(jnp.float32)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)            # (b,c,h,p,n)

    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", C_c, jnp.exp(cum),
                         h_prev.astype(x.dtype))
    y = (y + y_inter).reshape(Bsz, S, H, P)
    return y[:, :S_orig], h_final.astype(x.dtype)


def ssm_forward(p, x, ssm: SSMConfig, state=None, conv_state=None,
                d_model: int | None = None, seq_lens=None,
                kernel: str = "einsum"):
    """Full Mamba2 block (minus residual). x: (B, S, d).

    Training/prefill path. Returns (out, (ssm_state, conv_state)).

    ``kernel="pallas"`` runs the SSD core through the chunked Pallas scan
    (``kernels.ops.ssd_scan``); requires ``state is None`` (no carried-in
    initial state — training/scoring, not chunked prefill).

    ``seq_lens`` (B,) int32 marks positions >= seq_lens[b] as right-padding
    (bucketed prefill): their dt is zeroed — an *exact* no-op on the state
    recurrence (decay exp(0)=1, contribution dt·x⊗B=0, the same mechanism
    ``ssd_chunked`` uses for its own chunk padding) — and the returned
    conv_state is gathered from the window ending at each row's last real
    token instead of the (padded) end of the sequence.  Outputs at pad
    positions are garbage; real positions and both states are bit-identical
    to running the unpadded sequence.
    """
    B, S, d = x.shape
    di, nh, conv_dim = dims(d, ssm)
    N = ssm.d_state

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(x.dtype))
    z, xbc, dt = jnp.split(zxbcdt, [di, di + conv_dim], axis=-1)

    # causal depthwise conv over (x, B, C)
    pad = jnp.zeros((B, ssm.d_conv - 1, conv_dim), xbc.dtype) \
        if conv_state is None else conv_state
    xbc_pad = jnp.concatenate([pad, xbc], axis=1)
    if seq_lens is None:
        new_conv_state = xbc_pad[:, -(ssm.d_conv - 1):, :]
    else:
        # window ending at each row's last real token: xbc_pad index
        # d_conv-1+t holds input t, so inputs P-d_conv+1..P-1 live at
        # indices P..P+d_conv-2
        idx = (jnp.asarray(seq_lens, jnp.int32)[:, None]
               + jnp.arange(ssm.d_conv - 1)[None, :])
        new_conv_state = jnp.take_along_axis(xbc_pad, idx[:, :, None],
                                             axis=1)
    acc = jnp.zeros_like(xbc)
    for i in range(ssm.d_conv):
        acc = acc + xbc_pad[:, i:i + S, :] \
            * p["conv_w"][i][None, None, :].astype(acc.dtype)
    xbc = jax.nn.silu(acc + p["conv_b"][None, None, :].astype(acc.dtype))

    xs, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    xh = xs.reshape(B, S, nh, ssm.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    if seq_lens is not None:
        active = (jnp.arange(S)[None, :]
                  < jnp.asarray(seq_lens, jnp.int32)[:, None])
        dt = dt * active[..., None].astype(dt.dtype)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if kernel == "pallas":
        if state is not None:
            raise ValueError("kernel='pallas' does not take an initial "
                             "state; use the einsum path for chunked prefill")
        Q = min(ssm.chunk, S)
        padn = (Q - S % Q) % Q
        if padn:   # dt=0 pad rows are exact state no-ops (see ssd_chunked)
            xh_p = jnp.pad(xh, ((0, 0), (0, padn), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, padn), (0, 0)))
            Bm_p = jnp.pad(Bm, ((0, 0), (0, padn), (0, 0)))
            Cm_p = jnp.pad(Cm, ((0, 0), (0, padn), (0, 0)))
            y, h_final = ops.ssd_scan(xh_p, dt_p, A, Bm_p, Cm_p, ssm.chunk)
            y = y[:, :S]
        else:
            y, h_final = ops.ssd_scan(xh, dt, A, Bm, Cm, ssm.chunk)
    elif kernel == "einsum":
        y, h_final = ssd_chunked(xh, dt, A, Bm, Cm, ssm.chunk, h0=state)
    else:
        raise ValueError(f"unknown ssm kernel {kernel!r}")
    y = y + xh * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"].astype(y.dtype))
    return out.astype(x.dtype), (h_final, new_conv_state)


def ssm_decode_step(p, x, ssm: SSMConfig, state, conv_state,
                    kernel: str = "einsum"):
    """One-token recurrent step. x: (B, 1, d). state: (B, H, P, N),
    conv_state: (B, d_conv-1, conv_dim). Returns (out, (state, conv_state)).

    ``kernel="pallas"`` fuses the recurrence (decay + rank-1 update +
    readout) into ``kernels.ops.ssd_decode_step`` — one HBM round trip
    for the state, the update tensor never materialized."""
    B, _, d = x.shape
    di, nh, conv_dim = dims(d, ssm)
    N = ssm.d_state

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"].astype(x.dtype))[:, 0]
    z, xbc, dt = jnp.split(zxbcdt, [di, di + conv_dim], axis=-1)

    window = jnp.concatenate([conv_state.astype(xbc.dtype),
                              xbc[:, None, :]], axis=1)
    new_conv_state = window[:, 1:, :]
    conv_out = jnp.einsum("bkc,kc->bc", window,
                          p["conv_w"].astype(xbc.dtype)) \
        + p["conv_b"].astype(xbc.dtype)
    xbc = jax.nn.silu(conv_out)

    xs, Bm, Cm = jnp.split(xbc, [di, di + N], axis=-1)
    xh = xs.reshape(B, nh, ssm.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))    # (B, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    if kernel == "pallas":
        y, state = ops.ssd_decode_step(xh, dt, A, Bm, Cm, state)
    elif kernel == "einsum":
        dA = jnp.exp(dt * A[None, :])                           # (B, H)
        # h <- dA * h + dt * x ⊗ B
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt.astype(xh.dtype), xh, Bm)
        state = state * dA[..., None, None].astype(state.dtype) + upd
        y = jnp.einsum("bhpn,bn->bhp", state, Cm)
    else:
        raise ValueError(f"unknown decode kernel {kernel!r}")
    y = y + xh * p["D"][None, :, None].astype(y.dtype)
    y = y.reshape(B, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bk,kd->bd", y, p["out_proj"].astype(y.dtype))[:, None, :]
    return out.astype(x.dtype), (state, new_conv_state)
