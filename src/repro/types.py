"""Core config dataclasses shared across the framework.

Everything is a frozen dataclass so configs are hashable (usable as jit
static args) and safely shareable across threads in the simulator.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# Architecture families understood by the model registry.
ARCH_FAMILIES = (
    "dense",      # decoder-only transformer (GQA, optional SWA / local:global)
    "moe",        # decoder-only with mixture-of-experts FFN
    "ssm",        # attention-free Mamba2 (SSD)
    "hybrid",     # parallel attention + SSM heads per block (Hymba)
    "encdec",     # encoder-decoder (Seamless backbone)
    "vlm",        # decoder-only consuming a patch-embedding prefix (PaliGemma)
    "audio",      # alias of encdec with an audio-frame-embedding frontend stub
    "resnet3d",   # the paper's own 3-D ResNet action-recognition family
)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    shared_expert: bool = False      # Llama-4 style always-on shared expert
    router_aux_weight: float = 0.01  # load-balance loss weight


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2           # d_inner = expand * d_model
    chunk: int = 128          # SSD chunk length
    d_conv: int = 4           # depthwise conv width


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # one of ARCH_FAMILIES
    num_layers: int
    d_model: int
    num_heads: int                    # 0 for attention-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    # --- attention pattern ---
    sliding_window: int = 0           # 0 = full attention everywhere
    global_every: int = 0             # gemma3: every k-th layer is global
    global_layers: Tuple[int, ...] = ()  # hymba: explicit global layer ids
    rope_theta: float = 10_000.0
    # --- extras ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    prefix_len: int = 0               # vlm/audio: embedding prefix length
    num_classes: int = 0              # resnet3d: classifier width
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    act: str = "silu"
    # encdec: number of encoder layers (decoder uses num_layers)
    num_encoder_layers: int = 0
    source: str = ""                  # citation for this config

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.family not in ARCH_FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.family != "resnet3d":
            if self.head_dim == 0 and self.num_heads:
                object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
            if self.num_heads and self.num_heads % max(self.num_kv_heads, 1):
                raise ValueError(
                    f"{self.name}: num_heads {self.num_heads} not divisible by "
                    f"num_kv_heads {self.num_kv_heads}")
        if self.family in ("moe",) and self.moe is None:
            raise ValueError(f"{self.name}: moe family requires MoEConfig")
        if self.family in ("ssm", "hybrid") and self.ssm is None:
            raise ValueError(f"{self.name}: {self.family} requires SSMConfig")

    # ------------------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.family in ("encdec", "audio")

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can decode at 500k context (SSM / SWA-dominant)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    def window_for_layer(self, layer: int) -> int:
        """Effective attention window for a layer. 0 = full attention."""
        if self.sliding_window == 0:
            return 0
        if self.global_layers and layer in self.global_layers:
            return 0
        if self.global_every and (layer + 1) % self.global_every == 0:
            return 0
        return self.sliding_window

    # ------------------------------------------------------------------
    def reduced(self, num_layers: int = 2, d_model: int = 256,
                vocab: int = 512) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests.

        Keeps the head/kv ratio, the attention pattern kind, and the MoE/SSM
        structure, shrinking every width. <=4 experts, d_model<=512, 2 layers.
        """
        if self.family == "resnet3d":
            return dataclasses.replace(
                self, name=self.name + "-reduced",
                num_layers=2, d_model=32, num_classes=min(self.num_classes, 16))
        num_heads = max(2, min(4, self.num_heads)) if self.num_heads else 0
        kv = max(1, min(num_heads, self.num_kv_heads)) if num_heads else 0
        if num_heads and num_heads % kv:
            kv = 1
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=min(4, self.moe.num_experts),
                top_k=min(self.moe.top_k, min(4, self.moe.num_experts)))
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(
                self.ssm, d_state=min(16, self.ssm.d_state), head_dim=32,
                chunk=32)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=num_layers,
            num_encoder_layers=min(self.num_encoder_layers, num_layers),
            d_model=min(d_model, 512),
            num_heads=num_heads,
            num_kv_heads=kv,
            head_dim=(min(d_model, 512) // num_heads) if num_heads else 0,
            d_ff=2 * min(d_model, 512),
            vocab_size=vocab,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            global_layers=tuple(g for g in self.global_layers if g < num_layers),
            prefix_len=min(self.prefix_len, 8),
            moe=moe,
            ssm=ssm,
        )

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Approximate parameter count N (used for MODEL_FLOPS = 6*N*D)."""
        if self.family == "resnet3d":
            # handled by models.resnet3d.param_count
            from repro.models import resnet3d
            return resnet3d.param_count(self)
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        q = self.num_heads * hd
        kvd = self.num_kv_heads * hd
        attn = d * q + 2 * d * kvd + q * d
        mlp = 3 * d * f
        if self.moe is not None and self.moe.num_experts:
            mlp = self.moe.num_experts * 3 * d * f + d * self.moe.num_experts
            if self.moe.shared_expert:
                mlp += 3 * d * f
        ssm = 0
        if self.ssm is not None:
            di = self.ssm.expand * d
            # in/out projections + B,C state projections (SSD, grouped B/C)
            ssm = d * 2 * di + di * d + di * 2 * self.ssm.d_state
        per_layer = 2 * d  # norms
        if self.family == "ssm":
            per_layer += ssm
        elif self.family == "hybrid":
            per_layer += attn + mlp + ssm
        else:
            per_layer += attn + mlp
        total_layers = self.num_layers + self.num_encoder_layers
        n = total_layers * per_layer + v * d + d
        if not self.tie_embeddings:
            n += v * d
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k + shared experts count)."""
        if self.moe is None or not self.moe.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_experts = self.moe.top_k + (1 if self.moe.shared_expert else 0)
        inactive = (self.moe.num_experts - self.moe.top_k) * 3 * d * f
        return int(self.param_count() - self.num_layers * inactive)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


@dataclass(frozen=True)
class FedConfig:
    """Hyperparameters of the paper's Algorithm 1 (+ FedAvg baseline)."""
    num_clients: int = 4
    mixing_beta: float = 0.7          # β
    staleness_a: float = 0.5          # a in s(x) = (1+x)^{-a}
    prox_theta: float = 0.01          # θ, proximal regularization
    local_iters_min: int = 1          # H_min
    local_iters_max: int = 3          # H_max
    global_epochs: int = 80           # E
    lr: float = 1e-3                  # η
    momentum: float = 0.9
    weight_decay: float = 0.0
    max_staleness: int = 16           # K (Assumption 3)
    trainable: str = "all"            # "all" | "last_layer" (paper fine-tunes FC)
    compress_bits: int = 0            # 0 = off; 8 = int8 delta updates
    # per-round client subsampling (population-scale fleets, core/fleet.py):
    # sync draws this many clients per round; async keeps this many in
    # flight. 0 = whole population every round (legacy semantics).
    clients_per_round: int = 0
    seed: int = 0

    @property
    def imbalance_ratio(self) -> float:
        return self.local_iters_max / max(1, self.local_iters_min)


@dataclass(frozen=True)
class DistillConfig:
    """Knowledge-distillation stage config (paper §III-B)."""
    alpha: float = 0.5                # L = α L_cls + (1-α) L_KD
    temperature: float = 1.0          # L_KD = Σ((s-t)/T)²; T=1 = paper MSE
    lr: float = 0.1
    momentum: float = 0.9
    weight_decay: float = 1e-3
    batch_size: int = 128
    epochs: int = 200
    # chain of model names teacher -> TA... -> student (≥2 entries)
    chain: Tuple[str, ...] = ()
