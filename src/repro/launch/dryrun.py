"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, print memory/cost analysis, and write the roofline
JSON artifacts EXPERIMENTS.md reads.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # everything
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b \
        --shape train_4k --mesh pod                              # one combo
    PYTHONPATH=src python -m repro.launch.dryrun --list          # the matrix
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: the dry-run builds the 256/512-chip
# production meshes out of host placeholder devices. Never set globally.

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, shape_supported
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.roofline import analyze_compiled
from repro.sharding import specs as shspecs
from repro.types import FedConfig

PARAM_DTYPE = jnp.float32      # master weights (SGD momentum rides f32)
ACT_DTYPE = jnp.bfloat16
OUT_DIR = "experiments/dryrun"


def params_struct(cfg, dtype=PARAM_DTYPE):
    return jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), cfg, dtype))


def model_flops(cfg, shape) -> float:
    """6·N_active·D (train) or 2·N_active·D (forward-only decode/prefill)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # one token per sequence


def lower_combo(arch: str, shape_name: str, mesh, mesh_name: str,
                fed: FedConfig, constrain_acts: bool = True,
                opts: dict | None = None):
    """opts (all default off — the paper-faithful/naive BASELINE):
      param_dtype: 'f32'|'bf16'  — bf16 master weights (train/prefill)
      prefill_act: bool          — residual seq-sharding during prefill
                                   (pure collective overhead fwd-only;
                                   True in the baseline)
      serve_unroll: bool         — python-unroll decode layers
      window_slice: bool         — SWA layers read only their window of
                                   the cache (requires serve_unroll)
      moe_fullgrid_dispatch: bool — shard_map MoE dispatch over
                                   (data×model) instead of data
    """
    opts = dict(opts or {})
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips = mesh.devices.size
    pdtype = jnp.bfloat16 if opts.get("param_dtype") == "bf16" \
        else PARAM_DTYPE

    with mesh:
        if shape.kind in ("train", "prefill"):
            # prefill is lowered as the forward-only half of the same program
            pstruct = params_struct(cfg, pdtype)
            bstruct = registry.batch_spec(cfg, shape, ACT_DTYPE)
            if shape.kind == "train":
                tkw = {}
                if opts.get("q_chunk"):
                    tkw["q_chunk"] = int(opts["q_chunk"])
                if opts.get("loss_chunk"):
                    tkw["loss_chunk"] = int(opts["loss_chunk"])
                jf, _ = steps_mod.jit_train_step(
                    cfg, fed, mesh, shape, pstruct, bstruct,
                    constrain_acts=constrain_acts, donate=True,
                    moe_fullgrid=opts.get("moe_fullgrid_dispatch", False),
                    train_kwargs=tkw)
                opt_struct = jax.eval_shape(
                    steps_mod.sgd(fed.lr, fed.momentum).init, pstruct)
                lowered = jf.lower(pstruct, opt_struct, pstruct, bstruct)
            else:
                pspec = shspecs.param_pspecs(mesh, cfg, pstruct)
                bspec = shspecs.batch_pspecs(mesh, cfg, bstruct)
                use_act = opts.get("prefill_act", True) and constrain_acts
                ap = steps_mod.act_pspec(mesh, cfg, shape.seq_len) \
                    if use_act else None
                kw = {}
                if cfg.moe is not None and opts.get("moe_shardmap", True):
                    dp = shspecs.data_axes(mesh)
                    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
                    if opts.get("moe_fullgrid_dispatch"):
                        dp = tuple(shspecs.data_axes(mesh)) + ("model",)
                    kw["moe_ctx"] = {"mesh": mesh, "dp": dp}

                def fwd(params, batch):
                    return registry.loss_fn(params, cfg, batch, remat=False,
                                            act_pspec=ap, dtype=ACT_DTYPE,
                                            **kw)[0]

                # AOT lowering probe — never executed, only .lower()ed
                # repro-lint: disable=R1
                jf = jax.jit(fwd,
                             in_shardings=shspecs.named(mesh, (pspec, bspec)),
                             out_shardings=shspecs.named(mesh, P()))
                lowered = jf.lower(pstruct, bstruct)
        else:
            pstruct = params_struct(cfg, ACT_DTYPE)   # serving: bf16 weights
            ring = opts.get("ring_cache", False) and \
                cfg.family in ("dense", "moe", "hybrid", "vlm", "ssm") and \
                cfg.sliding_window > 0
            tok, cstruct, posst = registry.decode_spec(cfg, shape, ACT_DTYPE)
            if ring:
                from repro.models import lm as lm_mod
                cstruct = jax.eval_shape(
                    lambda: lm_mod.init_ring_cache(cfg, shape.global_batch,
                                                   shape.seq_len, ACT_DTYPE))
            jf, _ = steps_mod.jit_serve_step(
                cfg, mesh, shape, pstruct, cstruct, donate=True,
                unroll=opts.get("serve_unroll", False),
                window_slice=opts.get("window_slice", False), ring=ring)
            lowered = jf.lower(pstruct, tok, cstruct, posst)

        compiled = lowered.compile()

    rep = analyze_compiled(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=chips, model_flops_global=model_flops(cfg, shape))
    return compiled, rep


def lower_fl_aggregation(arch: str, mesh, mesh_name: str, fed: FedConfig,
                         beta_t: float = 0.7):
    """Lower the paper's server-side programs on the production mesh:

    1. the async mixing update w_t = (1-β_t)·w_{t-1} + β_t·w_new
       (Algorithm 1 server line) over FSDP×tensor-sharded parameters;
    2. synchronous FedAvg across the pod axis — per-pod client models
       stacked on a leading dim sharded over 'pod', mean lowers to a
       cross-pod all-reduce (the straggler-barrier collective the paper's
       async design removes).
    """
    cfg = get_config(arch)
    chips = mesh.devices.size
    pstruct = params_struct(cfg)
    results = {}
    with mesh:
        pspec = shspecs.param_pspecs(mesh, cfg, pstruct)
        mix = steps_mod.mixing_step(beta_t)
        # AOT lowering probe — never executed, only .lower()ed
        # repro-lint: disable=R1
        jf = jax.jit(mix, in_shardings=shspecs.named(mesh, (pspec, pspec)),
                     out_shardings=shspecs.named(mesh, pspec),
                     donate_argnums=(0,))
        comp = jf.lower(pstruct, pstruct).compile()
        results["mixing"] = analyze_compiled(
            comp, arch=arch, shape="mixing_update", mesh_name=mesh_name,
            chips=chips, model_flops_global=2.0 * cfg.param_count())
        if "pod" in mesh.axis_names:
            npod = mesh.shape["pod"]
            stacked = jax.tree_util.tree_map(
                lambda l: jax.ShapeDtypeStruct((npod,) + l.shape, l.dtype),
                pstruct)

            def _strip_pod(entry):
                # per-pod client models can't also FSDP-shard over 'pod'
                if entry == "pod":
                    return None
                if isinstance(entry, tuple):
                    rest = tuple(a for a in entry if a != "pod")
                    return rest[0] if len(rest) == 1 else (rest or None)
                return entry

            sspec = jax.tree_util.tree_map(
                lambda sp: P(*(("pod",) + tuple(_strip_pod(e)
                                                for e in tuple(sp)))),
                pspec, is_leaf=lambda x: isinstance(x, P))
            # AOT lowering probe — never executed, only .lower()ed
            # repro-lint: disable=R1
            jf2 = jax.jit(steps_mod.fedavg_step,
                          in_shardings=(shspecs.named(mesh, sspec),),
                          out_shardings=shspecs.named(mesh, pspec))
            comp2 = jf2.lower(stacked).compile()
            results["fedavg"] = analyze_compiled(
                comp2, arch=arch, shape="fedavg_pod", mesh_name=mesh_name,
                chips=chips, model_flops_global=npod * cfg.param_count())
    return results


def run_matrix(archs, shapes, meshes, constrain_acts=True, tag="baseline",
               out_dir=OUT_DIR, fed: FedConfig | None = None,
               verbose=True, opts: dict | None = None):
    fed = fed or FedConfig()
    os.makedirs(out_dir, exist_ok=True)
    rows, failures = [], []
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                ok, why = shape_supported(cfg, SHAPES[shape_name])
                key = f"{arch}|{shape_name}|{mesh_name}"
                if not ok:
                    rows.append({"arch": arch, "shape": shape_name,
                                 "mesh": mesh_name, "status": "SKIP",
                                 "reason": why})
                    if verbose:
                        print(f"[skip] {key}: {why}", flush=True)
                    continue
                t0 = time.time()
                try:
                    compiled, rep = lower_combo(arch, shape_name, mesh,
                                                mesh_name, fed,
                                                constrain_acts=constrain_acts,
                                                opts=opts)
                    row = rep.to_dict()
                    row["status"] = "OK"
                    row["compile_s"] = time.time() - t0
                    mem = compiled.memory_analysis()
                    row["memory_analysis"] = {
                        k: int(getattr(mem, k, 0)) for k in
                        ("temp_size_in_bytes", "argument_size_in_bytes",
                         "output_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes")}
                    rows.append(row)
                    fname = os.path.join(
                        out_dir, f"{tag}_{arch}_{shape_name}_{mesh_name}.json")
                    with open(fname, "w") as f:
                        json.dump(row, f, indent=1)
                    if verbose:
                        print(f"[ok]   {key}: compute={rep.compute_s*1e3:.2f}ms "
                              f"memory={rep.memory_s*1e3:.2f}ms "
                              f"collective={rep.collective_s*1e3:.2f}ms "
                              f"dominant={rep.dominant} "
                              f"peakmem={rep.peak_memory_bytes/2**30:.2f}GiB "
                              f"(compile {row['compile_s']:.1f}s)", flush=True)
                except Exception as e:  # noqa: BLE001
                    failures.append((key, repr(e)))
                    rows.append({"arch": arch, "shape": shape_name,
                                 "mesh": mesh_name, "status": "FAIL",
                                 "error": repr(e)})
                    if verbose:
                        print(f"[FAIL] {key}: {e}", flush=True)
                        traceback.print_exc()
    summary = os.path.join(out_dir, f"{tag}_summary.json")
    with open(summary, "w") as f:
        json.dump(rows, f, indent=1)
    return rows, failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--no-act-sharding", action="store_true",
                    help="disable the residual-stream sharding constraint "
                         "(the unoptimized baseline in §Perf)")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--param-dtype", choices=["f32", "bf16"], default="f32")
    ap.add_argument("--no-prefill-act", action="store_true")
    ap.add_argument("--serve-unroll", action="store_true")
    ap.add_argument("--window-slice", action="store_true")
    ap.add_argument("--moe-fullgrid", action="store_true")
    ap.add_argument("--ring-cache", action="store_true")
    ap.add_argument("--no-moe-shardmap", action="store_true",
                    help="naive pjit-only MoE dispatch (the pre-fix path)")
    ap.add_argument("--q-chunk", type=int, default=0)
    ap.add_argument("--loss-chunk", type=int, default=0)
    ap.add_argument("--fl-aggregation", action="store_true",
                    help="lower the FL server programs (mixing + cross-pod "
                         "FedAvg) instead of the train/serve matrix")
    args = ap.parse_args(argv)

    archs = args.arch or list(ASSIGNED_ARCHS)
    shapes = args.shape or list(SHAPES)
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    if args.fl_aggregation:
        os.makedirs(args.out, exist_ok=True)
        for mesh_name in meshes:
            mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
            for arch in archs:
                res = lower_fl_aggregation(arch, mesh, mesh_name,
                                           FedConfig())
                for kind, rep in res.items():
                    row = rep.to_dict()
                    fn = os.path.join(args.out,
                                      f"{args.tag}_fl_{kind}_{arch}_"
                                      f"{mesh_name}.json")
                    with open(fn, "w") as f:
                        json.dump(row, f, indent=1)
                    print(f"[ok] fl_{kind} {arch}|{mesh_name}: "
                          f"memory={rep.memory_s*1e3:.2f}ms "
                          f"collective={rep.collective_s*1e3:.2f}ms "
                          f"peak={rep.peak_memory_bytes/2**30:.2f}GiB",
                          flush=True)
        return 0

    if args.list:
        for a in archs:
            cfg = get_config(a)
            for s in shapes:
                ok, why = shape_supported(cfg, SHAPES[s])
                print(f"{a:28s} {s:12s} {'RUN' if ok else 'SKIP  ' + why}")
        return 0

    opts = {"param_dtype": args.param_dtype,
            "prefill_act": not args.no_prefill_act,
            "serve_unroll": args.serve_unroll,
            "window_slice": args.window_slice,
            "moe_fullgrid_dispatch": args.moe_fullgrid,
            "ring_cache": args.ring_cache,
            "moe_shardmap": not args.no_moe_shardmap,
            "q_chunk": args.q_chunk, "loss_chunk": args.loss_chunk}
    rows, failures = run_matrix(archs, shapes, meshes,
                                constrain_acts=not args.no_act_sharding,
                                tag=args.tag, out_dir=args.out, opts=opts)
    ok = sum(1 for r in rows if r.get("status") == "OK")
    sk = sum(1 for r in rows if r.get("status") == "SKIP")
    print(f"\n== dry-run: {ok} OK, {sk} skipped, {len(failures)} failed ==")
    for k, e in failures:
        print(f"  FAIL {k}: {e}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
