"""Production meshes. Functions (not module constants) so importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips (data, model).
    Multi-pod: 2×16×16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (CPU tests: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_fleet_mesh(n: int | None = None, edges: int | None = None):
    """Mesh for the sharded federated sync round.

    Default (``edges=None``): the 1-D ``('clients',)`` mesh — the round's
    client axis splits across it (core/fed_engine.py ``ShardedSyncRound``;
    specs in ``sharding.specs.fed_round_specs``). Defaults to every device
    this host has — CPU tests get a 1-device mesh, which runs the
    identical shard_map program unsharded.

    ``edges`` requests the two-level ``('edge', 'clients')`` mesh of the
    hierarchical edge-aggregator tree: ``edges`` edge aggregators, each
    owning ``n // edges`` client shards (clients psum to their edge, edges
    psum to the server — ``make_hierarchical_sync_round``). ``edges=0``
    picks the largest divisor of the device count ≤ its square root (a
    1-device host degenerates to the (1, 1) tree, same program).
    """
    n = n or len(jax.devices())
    if edges is None:
        return jax.make_mesh((n,), ("clients",))
    if edges == 0:
        edges = max(e for e in range(1, int(n ** 0.5) + 1) if n % e == 0)
    if edges < 1 or n % edges:
        raise ValueError(
            f"edges ({edges}) must be a positive divisor of the device "
            f"count ({n})")
    return jax.make_mesh((edges, n // edges), ("edge", "clients"))
