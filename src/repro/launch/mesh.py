"""Production meshes. Functions (not module constants) so importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16×16 = 256 chips (data, model).
    Multi-pod: 2×16×16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (CPU tests: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_fleet_mesh(n: int | None = None):
    """1-D ``('clients',)`` mesh for the sharded federated sync round.

    The round's client axis splits across it (core/fed_engine.py
    ``ShardedSyncRound``; specs in ``sharding.specs.fed_round_specs``).
    Defaults to every device this host has — CPU tests get a 1-device
    mesh, which runs the identical shard_map program unsharded.
    """
    n = n or len(jax.devices())
    return jax.make_mesh((n,), ("clients",))
