"""Two-stage pipeline driver: KD compression -> federated fine-tuning.

This is the paper's end-to-end story in one command (§III): stage 1
distils a server-side teacher into the deployable student over the
*full* (synthetic) dataset; stage 2 fine-tunes the distilled student
across the heterogeneous Jetson fleet on each client's *reduced* local
shard, asynchronously (Algorithm 1) or synchronously (FedAvg).

The distilled student params are the fine-tune init — the handoff is a
pytree of identical treedef/shapes to a scratch init, so the federated
engine's round program compiles once regardless of which init it gets.
Both stages run on the batched compiled engines (``core/distill.py``,
``core/fed_engine.py``); the whole pipeline is bit-reproducible under a
fixed ``--seed`` (``params_digest`` in the report certifies it).

Usage (CPU-scale smoke):
    PYTHONPATH=src python -m repro.launch.pipeline --smoke
    PYTHONPATH=src python -m repro.launch.pipeline --arch resnet3d-18 \
        --teacher resnet3d-34 --reduced --mode async --compare-scratch
"""
from __future__ import annotations

import argparse
import hashlib
import json
import time

import numpy as np

import jax

from repro.configs import get_config
from repro.core import distill, simulator
from repro.core.fleet import Fleet
from repro.data import BatchLoader, iid_partition, make_dataset_for
from repro.launch.train import build_fleet
from repro.models import registry
from repro.types import DistillConfig, FedConfig, ModelConfig


def params_digest(params) -> str:
    """sha256 over the param pytree's structure + raw leaf bytes: two runs
    of the pipeline agree iff their digests agree (bit-reproducibility)."""
    h = hashlib.sha256()
    leaves, treedef = jax.tree_util.tree_flatten(params)
    h.update(str(treedef).encode())
    for leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        h.update(str((arr.shape, str(arr.dtype))).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


def _finetune(params, cfg: ModelConfig, fed: FedConfig, ds, batch: int,
              mode: str, engine: str, seed: int):
    """Stage 2: federated fine-tune from ``params`` over an iid partition
    of the clients' reduced local dataset."""
    parts = iid_partition(max(len(ds), fed.num_clients * 8),
                          fed.num_clients, seed=seed) \
        if hasattr(ds, "__len__") else [None] * fed.num_clients
    data = [BatchLoader(ds, batch, steps=fed.local_iters_max,
                        seed=k, indices=parts[k])
            for k in range(fed.num_clients)]
    fleet = Fleet.from_lists(build_fleet(fed.num_clients), data)
    run = simulator.run_async if mode == "async" else simulator.run_sync
    res = run(params, cfg, fed, fleet, engine=engine)
    return res


def run_pipeline(arch: str = "resnet3d-18", teacher: str = "resnet3d-34",
                 reduced: bool = True, mode: str = "sync",
                 clients: int = 4, epochs: int = 4, batch: int = 4,
                 kd_steps: int = 8, teacher_steps: int = 8,
                 kd_lr: float = 0.01, kd_epoch_len: int | None = None,
                 kd_kernel: str = "pallas", engine: str = "scan",
                 codistill: bool = False, compare_scratch: bool = False,
                 eval_steps: int = 4, seed: int = 0):
    """Run KD compression then federated fine-tuning; returns
    ``(report, params)`` where report is a JSON-serializable dict and
    params the fine-tuned student pytree.
    """
    cfg = get_config(arch)
    tcfg = get_config(teacher)
    if reduced:
        cfg, tcfg = cfg.reduced(), tcfg.reduced()
    t0 = time.time()
    report = {"arch": cfg.name, "teacher": tcfg.name, "mode": mode,
              "kd_kernel": kd_kernel, "seed": seed}

    # ---- stage 1: server-side KD over the full dataset ----------------
    big = make_dataset_for(cfg, small=False, seed=seed)
    loader = BatchLoader(big, batch, steps=kd_steps, seed=seed)
    kd_eval = list(big.batches(batch, eval_steps, seed=999)) \
        if hasattr(big, "batches") else list(loader())
    dcfg = DistillConfig(lr=kd_lr, chain=(tcfg.name, cfg.name))
    if codistill:
        fleet, co = distill.run_codistill(
            [tcfg, cfg], dcfg, loader, kd_eval,
            rounds=max(1, kd_steps // 4), steps_per_round=min(4, kd_steps),
            seed=seed, kd_kernel=kd_kernel)
        params = fleet.member_params(1)       # the deployable student
        report["stage1"] = {"codistill": True,
                            "accuracy": co["accuracy"],
                            "rounds": int(co["losses"].shape[0])}
    else:
        params, stages = distill.run_chain(
            [tcfg, cfg], dcfg, loader, kd_eval, steps_per_stage=kd_steps,
            seed=seed, kd_kernel=kd_kernel,
            trained_teacher_steps=teacher_steps, epoch_len=kd_epoch_len)
        report["stage1"] = {"codistill": False, "stages": [
            {"teacher": s.teacher, "student": s.student,
             "accuracy": s.accuracy, "steps": len(s.losses),
             "compiles": s.compiles, "wall_s": s.wall_time_s}
            for s in stages]}
    report["stage1"]["digest"] = params_digest(params)

    # ---- stage 2: federated fine-tune on the clients' reduced data ----
    # Same seed as stage 1: the clients' reduced dataset draws the same
    # class programs as the server's full set, so KD transfer is real.
    fed = FedConfig(num_clients=clients, global_epochs=epochs, seed=seed)
    ds = make_dataset_for(cfg, small=True, seed=seed)
    res = _finetune(params, cfg, fed, ds, batch, mode, engine, seed)
    params = res.params
    held_out = list(ds.batches(batch, eval_steps, seed=777)) \
        if hasattr(ds, "batches") else []
    acc = distill.evaluate(params, cfg, held_out) if held_out else 0.0
    report["stage2"] = {"final_loss": res.final_loss,
                        "virtual_wall_s": res.wall_clock_s,
                        "accuracy": acc}
    report["params_digest"] = params_digest(params)

    if compare_scratch:
        # same fine-tune from a random init: the KD baseline of Table II
        scratch0 = registry.init_params(
            jax.random.fold_in(jax.random.PRNGKey(seed), 1), cfg)
        sres = _finetune(scratch0, cfg, fed, ds, batch, mode, engine, seed)
        sacc = distill.evaluate(sres.params, cfg, held_out) \
            if held_out else 0.0
        report["scratch"] = {"final_loss": sres.final_loss,
                             "accuracy": sacc}
    report["real_wall_s"] = time.time() - t0
    return report, params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="resnet3d-18")
    ap.add_argument("--teacher", default="resnet3d-34")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", choices=["async", "sync"], default="sync")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--kd-steps", type=int, default=8)
    ap.add_argument("--teacher-steps", type=int, default=8)
    ap.add_argument("--kd-lr", type=float, default=0.01)
    ap.add_argument("--kd-epoch-len", type=int, default=None,
                    help="KD scan-program length (default: whole stage)")
    ap.add_argument("--kd-kernel", choices=list(distill.KD_KERNELS),
                    default="pallas")
    ap.add_argument("--engine", choices=["scan", "loop", "shard"],
                    default="scan")
    ap.add_argument("--codistill", action="store_true",
                    help="stage 1 via codistillation (peer ensemble) "
                         "instead of the teacher->student chain")
    ap.add_argument("--compare-scratch", action="store_true",
                    help="also fine-tune from a random init and report it")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI preset (reduced, 2 clients, 2 epochs)")
    args = ap.parse_args(argv)

    kw = dict(arch=args.arch, teacher=args.teacher, reduced=args.reduced,
              mode=args.mode, clients=args.clients, epochs=args.epochs,
              batch=args.batch, kd_steps=args.kd_steps,
              teacher_steps=args.teacher_steps, kd_lr=args.kd_lr,
              kd_epoch_len=args.kd_epoch_len, kd_kernel=args.kd_kernel,
              engine=args.engine, codistill=args.codistill,
              compare_scratch=args.compare_scratch, seed=args.seed)
    if args.smoke:
        kw.update(reduced=True, clients=2, epochs=2, batch=2,
                  kd_steps=4, teacher_steps=2, eval_steps=2)
    report, _ = run_pipeline(**kw)
    print(json.dumps(report))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
