"""End-to-end training driver (runs on this host's real devices).

Implements the paper's full pipeline on synthetic data:
  stage 1 — server-side knowledge distillation (teacher -> TA -> student);
  stage 2 — federated fine-tuning of the student across a heterogeneous
            client fleet, asynchronously (Algorithm 1) or synchronously
            (FedAvg baseline) or centrally (no clients).

Usage (CPU-scale smoke):
    PYTHONPATH=src python -m repro.launch.train --arch resnet3d-18 \
        --mode async --epochs 20 --reduced
    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --mode central --steps 50 --reduced
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import save_params
from repro.configs import get_config
from repro.core import distill, simulator
from repro.core.algorithms import ALGORITHMS, make_algorithm
from repro.core.fleet import (ASYNC_ENGINES, EngineSpec, Fleet, FleetSpec,
                              JETSON_FLEET_HMDB51)
from repro.data import BatchLoader, iid_partition, make_dataset_for
from repro.models import registry
from repro.types import DistillConfig, FedConfig


def build_fleet(n: int):
    base = list(JETSON_FLEET_HMDB51)
    return tuple(base[i % len(base)] for i in range(n))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="resnet3d-18")
    ap.add_argument("--mode", choices=["async", "sync", "central"],
                    default="async")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--epochs", type=int, default=20,
                    help="global epochs E (async/sync)")
    ap.add_argument("--steps", type=int, default=50,
                    help="steps (central mode)")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--population", type=int, default=0,
                    help="total fleet population (streaming FleetSpec, "
                         "core/fleet.py): clients materialize on demand, "
                         "so this can be 10^6. 0 = resident fleet of "
                         "--clients devices (legacy)")
    ap.add_argument("--clients-per-round", type=int, default=0,
                    help="per-round subsample size m: sync draws m clients "
                         "per round, async keeps m in flight. 0 = the "
                         "whole population every round (legacy)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--beta", type=float, default=0.7)
    ap.add_argument("--a", type=float, default=0.5)
    ap.add_argument("--theta", type=float, default=0.01)
    ap.add_argument("--trainable", choices=["all", "last_layer"],
                    default="all")
    ap.add_argument("--engine", choices=[e.value for e in EngineSpec],
                    default="scan",
                    help="client execution: compiled lax.scan/vmap engine "
                         "(heterogeneous H^k batches via the padded "
                         "masked scan), 'shard' to additionally split the "
                         "sync round's client axis over this host's "
                         "devices, 'hier' for the two-level edge-"
                         "aggregator tree over the ('edge','clients') "
                         "mesh (both sync-only), or the legacy "
                         "per-iteration loop")
    ap.add_argument("--algorithm", choices=sorted(ALGORITHMS),
                    default="fedprox",
                    help="federated algorithm (core/algorithms.py): "
                         "'fedprox' is the paper's proximal local SGD "
                         "(default; identical to the pre-algorithm-layer "
                         "behavior), 'scaffold' adds SCAFFOLD control "
                         "variates against client drift, 'lowrank' ships "
                         "capacity-scaled low-rank/masked submodel "
                         "updates for constrained uplinks")
    ap.add_argument("--async-window", type=float, default=0.0,
                    help="staleness-bounded micro-batching window W in "
                         "virtual seconds (async mode only): receives "
                         "finishing within W of each other apply as one "
                         "fused server mix and re-dispatch as one padded "
                         "batched program; 0 = event-by-event")
    ap.add_argument("--distill-first", action="store_true",
                    help="run a tiny teacher->student KD stage first "
                         "(see launch/pipeline.py for the full two-stage "
                         "KD -> federated fine-tune driver)")
    ap.add_argument("--kd-kernel", choices=list(distill.KD_KERNELS),
                    default="pallas",
                    help="KD loss implementation: fused Pallas kernel "
                         "(default) or the eager jnp parity oracle")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} family={cfg.family} mode={args.mode}")

    key = jax.random.PRNGKey(args.seed)
    params = registry.init_params(key, cfg)

    if args.distill_first and cfg.family == "resnet3d":
        teacher_cfg = get_config("resnet3d-34")
        if args.reduced:
            teacher_cfg = teacher_cfg.reduced()
        big = make_dataset_for(cfg, small=False, seed=args.seed)
        loader = BatchLoader(big, args.batch, steps=16, seed=args.seed)
        eval_b = list(big.batches(args.batch, 4, seed=999))
        dcfg = DistillConfig(lr=0.01, chain=(teacher_cfg.name, cfg.name))
        params, stages = distill.run_chain(
            [teacher_cfg, cfg], dcfg, loader, eval_b,
            steps_per_stage=16, seed=args.seed, trained_teacher_steps=16,
            kd_kernel=args.kd_kernel)
        for st in stages:
            print(f"  KD {st.teacher} -> {st.student}: "
                  f"acc={st.accuracy:.3f} ({st.wall_time_s:.1f}s)")

    population = args.population or args.clients
    fed = FedConfig(num_clients=population, global_epochs=args.epochs,
                    mixing_beta=args.beta, staleness_a=args.a,
                    prox_theta=args.theta, lr=args.lr,
                    trainable=args.trainable,
                    clients_per_round=args.clients_per_round,
                    seed=args.seed)
    ds = make_dataset_for(cfg, small=True, seed=args.seed + 1)
    t0 = time.time()

    if args.mode == "central":
        from repro.core.fedasync import make_client_step
        from repro.optim import trainable_mask
        step, opt = make_client_step(cfg, fed)
        mask = trainable_mask(params, fed.trainable)
        opt_state = opt.init(params)
        anchor = params
        for i, batch in enumerate(ds.batches(args.batch, args.steps,
                                             seed=args.seed)):
            params, opt_state, loss = step(params, opt_state, anchor, batch,
                                           mask)
            if i % 10 == 0:
                print(f"  step {i:4d} loss {float(loss):.4f}")
        result = {"mode": "central", "final_loss": float(loss),
                  "wall_s": time.time() - t0}
    else:
        if args.population:
            # streaming fleet: clients (profile, shard, H^k) materialize on
            # demand, so resident state is O(sampled), not O(population)
            fleet = Fleet.from_spec(FleetSpec(
                population=population, profiles=JETSON_FLEET_HMDB51,
                dataset=ds, batch_size=args.batch,
                steps=fed.local_iters_max, seed=args.seed,
                partition="shared"))
        else:
            profiles = build_fleet(args.clients)
            parts = iid_partition(max(len(ds), args.clients * 8),
                                  args.clients, seed=args.seed) \
                if hasattr(ds, "__len__") else [None] * args.clients
            data = [BatchLoader(ds, args.batch, steps=fed.local_iters_max,
                                seed=k, indices=parts[k])
                    for k in range(args.clients)]
            fleet = Fleet.from_lists(profiles, data)
        run = simulator.run_async if args.mode == "async" \
            else simulator.run_sync
        eng = args.engine
        if args.mode == "async" \
                and EngineSpec.from_str(eng) not in ASYNC_ENGINES:
            # the async path has no fleet-wide round to shard; its bursts
            # batch through the padded vmap program instead
            print(f"  engine={eng} is sync-only; async uses engine=scan")
            eng = "scan"
        kwargs = {}
        if args.mode == "async":
            kwargs["window"] = args.async_window
        if args.algorithm != "fedprox":
            # fedprox stays on the (bit-identical) default paths
            kwargs["algorithm"] = make_algorithm(args.algorithm)
        res = run(params, cfg, fed, fleet, engine=eng, **kwargs)
        params = res.params
        print(f"  virtual wall-clock {res.wall_clock_s:.0f}s "
              f"final loss {res.final_loss:.4f}")
        if args.mode == "async":
            print(f"  staleness histogram: {res.staleness_hist}")
            if args.async_window > 0:
                print(f"  receive-group histogram (W={args.async_window}): "
                      f"{res.group_hist}")
        result = {"mode": args.mode, "algorithm": args.algorithm,
                  "final_loss": res.final_loss,
                  "virtual_wall_s": res.wall_clock_s,
                  "real_wall_s": time.time() - t0}

    if args.ckpt:
        save_params(params, args.ckpt, extra=result)
        print(f"  saved {args.ckpt}")
    print(json.dumps(result))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
