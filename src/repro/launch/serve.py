"""Batched serving driver: prefill + autoregressive decode on this host.

Serves any LM-family architecture (reduced configs on CPU) with a batched
request queue — the inference half of the framework the paper's edge
deployment implies (Table V measures per-device inference times).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b \
        --reduced --batch 4 --prompt-len 32 --gen 16

``--continuous`` switches to the slot-based continuous batcher
(core/serving.py): a mixed-length request stream is served with
bucketed prefill (``--prefill-buckets`` sets the smallest bucket;
0 = per-request-length prefill) and per-layer-kind decode
(``--decode-mode ring``: SWA ring buffers + ladder-bucketed K-extents;
``--decode-mode uniform`` streams the full cache, the parity oracle).
The run reports compile counts — the bounded-compile discipline
docs/serving.md documents.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-12b \
        --reduced --continuous --requests 16 --prefill-buckets 8 \
        --decode-mode ring
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import registry
from repro.types import ShapeConfig


def serve_continuous(cfg, args) -> int:
    from repro.core.serving import ContinuousBatcher
    rng = np.random.default_rng(args.seed)
    params = registry.init_params(jax.random.PRNGKey(args.seed), cfg)
    max_len = args.prompt_len + args.gen
    srv = ContinuousBatcher(params, cfg, max_slots=args.batch,
                            max_len=max_len,
                            min_bucket=args.prefill_buckets,
                            decode_mode=args.decode_mode,
                            decode_kernel=args.decode_kernel)
    lengths = rng.integers(1, args.prompt_len + 1, args.requests)
    for n in lengths:
        srv.submit(rng.integers(0, cfg.vocab_size, int(n), dtype=np.int32),
                   max_new=args.gen)
    t0 = time.perf_counter()
    done = srv.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests ({len(set(map(int, lengths)))} "
          f"distinct prompt lengths) in {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} gen tok/s)")
    print(f"prefill buckets: {list(srv.buckets) or 'off (per-length)'}")
    print(f"decode mode: {srv.decode_mode} (K-extent ladder: "
          f"{list(srv.decode_buckets) or 'n/a (single program)'})")
    print(f"compiles: prefill={srv.prefill_compiles} "
          f"decode={srv.decode_compiles} total={srv.num_compiled}")
    print(f"admit group sizes {{size: count}}: {srv.group_admits}")
    print(f"bucket use {{bucket: programs run}}: {srv.bucket_hist}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="batch size; decode slots in --continuous mode")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="slot-based continuous batching over a "
                         "mixed-length request stream")
    ap.add_argument("--requests", type=int, default=16,
                    help="stream size in --continuous mode")
    ap.add_argument("--prefill-buckets", type=int, default=8,
                    help="smallest prefill bucket (power-of-two ladder up "
                         "to max_len); 0 = per-request-length prefill")
    ap.add_argument("--decode-mode", choices=["ring", "uniform"],
                    default="ring",
                    help="ring: per-layer-kind decode caches (SWA ring "
                         "buffers + ladder-bucketed K-extents); uniform: "
                         "legacy full-cache decode (parity oracle)")
    ap.add_argument("--decode-kernel", choices=["pallas", "einsum"],
                    default="pallas",
                    help="ring-mode decode attends/recurrence: fused "
                         "Pallas kernels (default) or the jnp einsum "
                         "parity oracle")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family == "resnet3d":
        raise SystemExit("resnet3d is a clip classifier; use train.py")
    if args.continuous:
        return serve_continuous(cfg, args)
    print(f"serving {cfg.name} ({cfg.family}) batch={args.batch}")

    rng = np.random.default_rng(args.seed)
    params = registry.init_params(jax.random.PRNGKey(args.seed), cfg)
    max_len = args.prompt_len + args.gen
    cache = registry.init_cache(cfg, args.batch, max_len, jnp.float32)

    # synthesize the prompt batch from the registry's canonical spec
    # (batch_spec text length is S - prefix_len, so ask for prompt+prefix)
    shape = ShapeConfig(name="serve", global_batch=args.batch,
                        seq_len=args.prompt_len + cfg.prefix_len,
                        kind="decode")
    batch = registry.synth_batch(rng, cfg, shape, act_dtype=jnp.float32)
    batch.pop("labels", None)           # generation, not scoring
    if cfg.is_encdec:
        batch = {"src_embeds": batch["src_embeds"]}

    t0 = time.perf_counter()
    if cfg.is_encdec:
        cache = registry.prefill(params, cfg, batch, cache)
        tok = jnp.zeros((args.batch,), jnp.int32)  # BOS
        start_pos = 0
    else:
        logits, cache = registry.prefill(params, cfg, batch, cache,
                                         q_chunk=min(1024, args.prompt_len))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        start_pos = args.prompt_len + cfg.prefix_len
    t_prefill = time.perf_counter() - t0

    # one decode program for the whole benchmark run; compiled exactly once
    # repro-lint: disable=R1
    decode = jax.jit(
        lambda p, t, c, pos: registry.decode_step(p, cfg, t, c, pos))
    out_tokens = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = decode(params, tok, cache, jnp.int32(start_pos + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    tok.block_until_ready()
    t_decode = time.perf_counter() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({args.batch * args.prompt_len / max(t_prefill, 1e-9):.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:.1f} ms "
          f"({args.batch * (args.gen - 1) / max(t_decode, 1e-9):.1f} tok/s, "
          f"{t_decode / max(args.gen - 1, 1) * 1e3:.1f} ms/step)")
    print(f"sample generations (first 8 token ids):\n{gen[:, :8]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
