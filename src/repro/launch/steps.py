"""jit-able step functions shared by the trainer, server and dry-run.

The *FL client local step* (paper Algorithm 1, client side) is the lowered
training program: task grads + proximal term θ(w - w_t), SGD-momentum
update. ``serve_step`` is one token of autoregressive decode against a
pre-allocated cache. ``mixing_step``/``fedavg_step`` are the server-side
aggregation programs (lowered across the pod axis on the multi-pod mesh).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import registry
from repro.optim import sgd
from repro.optim.proximal import proximal_grad
from repro.sharding import specs as shspecs
from repro.types import FedConfig, ModelConfig, ShapeConfig


def act_pspec(mesh: Mesh, cfg: ModelConfig, seq_len: int) -> Optional[P]:
    """Residual-stream sharding constraint (sequence parallelism): shard the
    sequence dim over 'model' between layers so stored remat residuals are
    16× smaller. Only when divisible."""
    if cfg.family == "resnet3d":
        return None
    dp = shspecs.data_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    model = shspecs._maybe(mesh, "model", seq_len)
    return P(dp, model, None)


def make_train_step(cfg: ModelConfig, fed: FedConfig, mesh: Mesh,
                    seq_len: int = 0, proximal: bool = True,
                    loss_kwargs: Optional[dict] = None,
                    constrain_acts: bool = True):
    """FL client local step: (params, opt_state, anchor, batch) ->
    (params, opt_state, loss)."""
    opt = sgd(fed.lr, fed.momentum, fed.weight_decay)
    loss_kwargs = dict(loss_kwargs or {})
    if cfg.family != "resnet3d":
        loss_kwargs.setdefault("dtype", jnp.bfloat16)  # bf16 compute
    if constrain_acts and cfg.family != "resnet3d" and seq_len:
        ap = act_pspec(mesh, cfg, seq_len)
        if ap is not None:
            loss_kwargs.setdefault("act_pspec", ap)
        if cfg.moe is not None:
            dp = shspecs.data_axes(mesh)
            dp = dp if len(dp) > 1 else (dp[0] if dp else None)
            # per-data-shard MoE dispatch (shard_map) — see models/moe.py
            loss_kwargs.setdefault("moe_ctx", {"mesh": mesh, "dp": dp})

    def loss(params, batch):
        return registry.loss_fn(params, cfg, batch, **loss_kwargs)[0]

    def step(params, opt_state, anchor, batch):
        l, grads = jax.value_and_grad(loss)(params, batch)
        if proximal:
            grads = proximal_grad(grads, params, anchor, fed.prox_theta)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, l

    return step, opt


def make_serve_step(cfg: ModelConfig, unroll: bool = False,
                    window_slice: bool = False, ring: bool = False):
    """(params, token, cache, pos) -> (next_token, cache)."""
    from repro.models import lm
    kw = {}
    if unroll and cfg.family in ("dense", "moe", "hybrid", "vlm", "ssm"):
        kw = {"unroll": True, "window_slice": window_slice}

    def step(params, token, cache, pos):
        if ring and cfg.family in ("dense", "moe", "hybrid", "vlm", "ssm"):
            logits, cache = lm.decode_step_ring(params, cfg, token, cache,
                                                pos)
        else:
            logits, cache = registry.decode_step(params, cfg, token, cache,
                                                 pos, **kw)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, cache

    return step


def mixing_step(beta_t):
    """Paper server update w_t = (1-β_t)·w_{t-1} + β_t·w_new (async FL)."""
    def step(w_prev, w_new):
        return jax.tree_util.tree_map(
            lambda a, b: ((1 - beta_t) * a.astype(jnp.float32)
                          + beta_t * b.astype(jnp.float32)).astype(a.dtype),
            w_prev, w_new)
    return step


def fedavg_step(w_stacked):
    """Cross-pod FedAvg: client models stacked on a leading axis sharded
    over 'pod'; the mean lowers to an all-reduce across pod links."""
    return jax.tree_util.tree_map(
        lambda s: jnp.mean(s.astype(jnp.float32), axis=0).astype(s.dtype),
        w_stacked)


# ---------------------------------------------------------------------------
# Sharding-annotated jit wrappers (used by dryrun / train / serve)
# ---------------------------------------------------------------------------

def jit_train_step(cfg: ModelConfig, fed: FedConfig, mesh: Mesh,
                   shape: ShapeConfig, params_shape, batch_shape,
                   proximal: bool = True, constrain_acts: bool = True,
                   donate: bool = True, moe_fullgrid: bool = False,
                   train_kwargs: Optional[dict] = None):
    """Returns (jitted_fn, (in_shardings, out_shardings)) for
    step(params, opt_state, anchor, batch)."""
    lk = dict(train_kwargs or {})
    if moe_fullgrid and cfg.moe is not None:
        dp = tuple(shspecs.data_axes(mesh)) + ("model",)
        lk["moe_ctx"] = {"mesh": mesh, "dp": dp}
    step, opt = make_train_step(cfg, fed, mesh, seq_len=shape.seq_len,
                                proximal=proximal, loss_kwargs=lk,
                                constrain_acts=constrain_acts)
    pspec = shspecs.param_pspecs(mesh, cfg, params_shape)
    opt_shape = jax.eval_shape(opt.init, params_shape)
    ospec = jax.tree_util.tree_map(
        lambda _: P(), opt_shape,
        is_leaf=lambda x: x is None)
    # momentum shards like its param; step counter replicates
    ospec = {"mom": pspec if opt_shape["mom"] is not None else None,
             "step": P()}
    bspec = shspecs.batch_pspecs(mesh, cfg, batch_shape)
    in_sh = (pspec, ospec, pspec, bspec)
    out_sh = (pspec, ospec, P())
    # Sharded once-per-launch driver jit: JitCache has no in_/out_shardings
    # support, and the AOT analyzer accounts for these compiles directly.
    # repro-lint: disable=R1
    jf = jax.jit(step, in_shardings=shspecs.named(mesh, in_sh),
                 out_shardings=shspecs.named(mesh, out_sh),
                 donate_argnums=(0, 1) if donate else ())
    return jf, (in_sh, out_sh)


def jit_serve_step(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig,
                   params_shape, cache_shape, donate: bool = True,
                   unroll: bool = False, window_slice: bool = False,
                   ring: bool = False):
    step = make_serve_step(cfg, unroll=unroll, window_slice=window_slice,
                           ring=ring)
    pspec = shspecs.param_pspecs(mesh, cfg, params_shape)
    cspec = shspecs.cache_pspecs(mesh, cfg, cache_shape, shape.global_batch)
    tspec = shspecs.token_pspec(mesh, shape.global_batch)
    in_sh = (pspec, tspec, cspec, P())
    out_sh = (tspec, cspec)
    # repro-lint: disable=R1  (sharded driver jit; see jit_train_step note)
    jf = jax.jit(step, in_shardings=shspecs.named(mesh, in_sh),
                 out_shardings=shspecs.named(mesh, out_sh),
                 donate_argnums=(2,) if donate else ())
    return jf, (in_sh, out_sh)
