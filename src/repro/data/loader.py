"""Minimal batching utilities shared by the trainer and the simulator."""
from __future__ import annotations

from typing import Callable, Iterator

import numpy as np


class BatchLoader:
    """Re-startable loader: calling it returns a fresh finite iterator,
    which is exactly the `client_data[k]()` contract of the simulator."""

    def __init__(self, dataset, batch_size: int, steps: int,
                 seed: int = 0, indices: np.ndarray | None = None):
        self.dataset = dataset
        self.batch_size = batch_size
        self.steps = steps
        self.seed = seed
        self.indices = indices
        self._epoch = 0

    def __call__(self) -> Iterator[dict]:
        self._epoch += 1
        return self.dataset.batches(self.batch_size, self.steps,
                                    seed=(self.seed, self._epoch),
                                    indices=self.indices)
