"""Synthetic datasets standing in for Kinetics / HMDB51 / UCF101.

The repro gate (DESIGN.md): the real video datasets (400 GB) and the Jetson
testbed are unavailable, and the paper's claims are about *relative*
behaviour (KD > scratch, async ≈ sync accuracy at lower wall-clock). The
synthetic action dataset is constructed so those relative effects are
reproducible:

- each class c has a latent "motion program" (direction, speed, texture seed)
  rendering short clips of a moving Gaussian blob over structured noise;
- class manifolds overlap (configurable noise) so a large teacher separates
  them better than a small student trained from scratch on few samples —
  the regime where KD transfers dark knowledge;
- a "small" dataset (HMDB51 stand-in) is a low-sample, higher-noise split
  and a "large" one (Kinetics stand-in) has many samples per class.

The LM dataset is an order-k Markov chain over a small vocab for the
transformer-family architectures (used by FL integration tests).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass
class SyntheticActionDataset:
    """Procedural video-clip classification."""
    num_classes: int
    samples_per_class: int
    frames: int = 4
    size: int = 16
    noise: float = 0.35
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        C = self.num_classes
        # latent motion programs
        self.dirs = rng.normal(size=(C, 2))
        self.dirs /= np.linalg.norm(self.dirs, axis=1, keepdims=True) + 1e-9
        self.speeds = rng.uniform(0.5, 2.5, size=(C,))
        self.widths = rng.uniform(1.5, 3.5, size=(C,))
        self.textures = rng.normal(size=(C, self.size, self.size, 3)) * 0.3

    def __len__(self):
        return self.num_classes * self.samples_per_class

    def render(self, cls: int, rng: np.random.Generator) -> np.ndarray:
        T, S = self.frames, self.size
        yy, xx = np.mgrid[0:S, 0:S].astype(np.float32)
        start = rng.uniform(S * 0.25, S * 0.75, size=(2,))
        clip = np.empty((T, S, S, 3), np.float32)
        d = self.dirs[cls] + rng.normal(scale=0.15, size=2)
        sp = self.speeds[cls] * rng.uniform(0.8, 1.2)
        w = self.widths[cls]
        for t in range(T):
            cx, cy = start + d * sp * t
            blob = np.exp(-(((xx - cx) % S) ** 2 + ((yy - cy) % S) ** 2)
                          / (2 * w * w))
            frame = blob[..., None] + self.textures[cls]
            clip[t] = frame
        clip += rng.normal(scale=self.noise, size=clip.shape)
        return clip

    def batches(self, batch_size: int, steps: int, seed: int = 0,
                indices: np.ndarray | None = None):
        """Yields dicts {clips, labels}. ``indices`` restricts to a client
        shard (see partition.py)."""
        rng = np.random.default_rng((self.seed, seed))
        n = len(self) if indices is None else len(indices)
        for _ in range(steps):
            if indices is None:
                labels = rng.integers(0, self.num_classes, size=batch_size)
            else:
                pick = rng.integers(0, n, size=batch_size)
                labels = (indices[pick] % self.num_classes).astype(np.int64)
            clips = np.stack([self.render(int(c), rng) for c in labels])
            yield {"clips": clips.astype(np.float32),
                   "labels": labels.astype(np.int32)}


@dataclass
class SyntheticLMDataset:
    """Order-1 Markov chain token stream with class-like modes."""
    vocab: int
    seq_len: int
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        raw = rng.dirichlet(np.full(self.vocab, 0.05), size=self.vocab)
        self.T = raw / raw.sum(axis=1, keepdims=True)

    def sample(self, rng: np.random.Generator, batch: int) -> np.ndarray:
        out = np.empty((batch, self.seq_len + 1), np.int64)
        out[:, 0] = rng.integers(0, self.vocab, size=batch)
        for i in range(self.seq_len):
            probs = self.T[out[:, i]]
            cum = probs.cumsum(axis=1)
            u = rng.random((batch, 1))
            out[:, i + 1] = (u > cum).sum(axis=1)
        return out

    def batches(self, batch_size: int, steps: int, seed: int = 0,
                indices=None):
        rng = np.random.default_rng((self.seed, seed))
        for _ in range(steps):
            toks = self.sample(rng, batch_size)
            yield {"tokens": toks[:, :-1].astype(np.int32),
                   "labels": toks[:, 1:].astype(np.int32)}


def stack_batches(batches, limit: int | None = None):
    """Stack an iterable of dict batches into one pytree with leading axis H.

    This is the wire format of the scan client engine
    (``repro.core.fed_engine``): H per-iteration batches become arrays of
    shape (H, batch, ...) so local training compiles to a single
    ``lax.scan``. ``limit`` caps H (the simulator's per-client budget).
    Returns None when the iterable is empty (legacy loop semantics: the
    client returns the global model unchanged).
    """
    import itertools
    # islice, not enumerate+break: the latter would pull (and waste) one
    # batch past the limit, breaking consumption parity with the legacy
    # ``zip(range(H), batches)`` loop on shared iterators
    out = list(itertools.islice(batches, limit))
    if not out:
        return None
    return {k: np.stack([b[k] for b in out]) for k in out[0]}


def make_dataset_for(cfg, *, small: bool = True, seed: int = 0):
    """Dataset stand-in appropriate for a model family.

    small=True  -> HMDB51-like (few samples, noisy; clients' fine-tune data)
    small=False -> Kinetics-like (many samples; server-side distillation)
    """
    if cfg.family == "resnet3d":
        return SyntheticActionDataset(
            num_classes=min(cfg.num_classes, 16 if small else 32),
            samples_per_class=8 if small else 64,
            noise=0.5 if small else 0.3,
            seed=seed)
    return SyntheticLMDataset(vocab=cfg.vocab_size,
                              seq_len=64, seed=seed)
