"""Federated data partitioning: IID and Dirichlet non-IID splits.

The paper distributes HMDB51/UCF101 evenly (≈500MB / 1.73GB per client);
non-IID Dirichlet splits support the future-work axis the paper names.
"""
from __future__ import annotations

import numpy as np


def iid_partition(num_items: int, num_clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(num_items)
    return [np.sort(s) for s in np.array_split(perm, num_clients)]


def dirichlet_partition(labels: np.ndarray, num_clients: int,
                        alpha: float = 0.5, seed: int = 0):
    """Class-skewed split; alpha→∞ recovers IID, alpha→0 one-class clients."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    shards: list[list[int]] = [[] for _ in range(num_clients)]
    for c in classes:
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(num_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for k, part in enumerate(np.split(idx, cuts)):
            shards[k].extend(part.tolist())
    return [np.sort(np.array(s, dtype=np.int64)) for s in shards]
