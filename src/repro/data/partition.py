"""Federated data partitioning: IID and Dirichlet non-IID splits.

The paper distributes HMDB51/UCF101 evenly (≈500MB / 1.73GB per client);
non-IID Dirichlet splits support the future-work axis the paper names.
"""
from __future__ import annotations

import numpy as np


def iid_partition(num_items: int, num_clients: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(num_items)
    return [np.sort(s) for s in np.array_split(perm, num_clients)]


def iid_shard(num_items: int, num_clients: int, client: int, seed: int = 0,
              perm: np.ndarray | None = None):
    """ONE client's IID shard, without materializing every client's list.

    Bit-identical to ``iid_partition(num_items, num_clients, seed)[client]``
    but O(num_items) instead of O(num_items + num_clients) — the streaming
    fleet (``core.fleet.FleetSpec``) materializes a sampled client's shard
    on demand, so a 10^6-client population never allocates 10^6 index
    arrays. ``perm`` lets a caller reuse the (dataset-sized, population-
    independent) permutation across clients instead of re-drawing it.
    """
    if not 0 <= client < num_clients:
        raise ValueError(f"client {client} outside [0, {num_clients})")
    if perm is None:
        perm = np.random.default_rng(seed).permutation(num_items)
    # np.array_split boundaries: the first (num_items % num_clients) shards
    # get one extra item
    q, r = divmod(num_items, num_clients)
    start = client * q + min(client, r)
    stop = start + q + (1 if client < r else 0)
    return np.sort(perm[start:stop])


def dirichlet_partition(labels: np.ndarray, num_clients: int,
                        alpha: float = 0.5, seed: int = 0):
    """Class-skewed split; alpha→∞ recovers IID, alpha→0 one-class clients."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    shards: list[list[int]] = [[] for _ in range(num_clients)]
    for c in classes:
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet(np.full(num_clients, alpha))
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for k, part in enumerate(np.split(idx, cuts)):
            shards[k].extend(part.tolist())
    return [np.sort(np.array(s, dtype=np.int64)) for s in shards]
