from repro.data.synthetic import (SyntheticActionDataset, SyntheticLMDataset,
                                  make_dataset_for, stack_batches)
from repro.data.partition import dirichlet_partition, iid_partition
from repro.data.loader import BatchLoader

__all__ = ["SyntheticActionDataset", "SyntheticLMDataset", "make_dataset_for",
           "stack_batches", "iid_partition", "dirichlet_partition",
           "BatchLoader"]
