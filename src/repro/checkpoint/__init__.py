from repro.checkpoint.ckpt import load_params, load_server_state, \
    save_params, save_server_state

__all__ = ["save_params", "load_params", "save_server_state",
           "load_server_state"]
