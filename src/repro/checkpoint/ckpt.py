"""Pytree checkpointing: npz payload + JSON manifest (no external deps).

Paths inside the pytree are flattened to '/'-joined keys. Server state
(global epoch, update count, fed config echo) rides in the manifest.
"""
from __future__ import annotations

import json
import os
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp


def _flatten(params) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_params(params, path: str, extra: dict | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(params)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    treedef = jax.tree_util.tree_structure(params)
    manifest = {"treedef": str(treedef), "keys": sorted(flat),
                "extra": extra or {}}
    with open(_manifest_path(path), "w") as f:
        json.dump(manifest, f, indent=1)


def _manifest_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".json"


def load_params(template, path: str):
    """Restore into the structure of ``template`` (same treedef)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in paths:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = jnp.asarray(data[key])
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: checkpoint {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_server_state(state, path: str, fed=None):
    extra = {"t": int(state.t), "total_updates": int(state.total_updates)}
    if fed is not None:
        extra["fed"] = {k: v for k, v in fed.__dict__.items()}
    save_params(state.params, path, extra=extra)


def load_server_state(template_params, path: str):
    from repro.core.fedasync import ServerState
    params = load_params(template_params, path)
    with open(_manifest_path(path)) as f:
        manifest = json.load(f)
    extra = manifest["extra"]
    return ServerState(params=params, t=extra["t"],
                       total_updates=extra["total_updates"])
