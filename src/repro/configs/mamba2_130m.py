"""Mamba2 130M. [arXiv:2405.21060]

24L d_model=768 (attention-free) vocab=50280, ssm_state=128 — SSD
(state-space duality) blocks: d_inner = 2*d_model = 1536, head_dim 64
-> 24 SSD heads.
"""
from repro.types import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
