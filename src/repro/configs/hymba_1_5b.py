"""Hymba 1.5B. [arXiv:2411.13676]

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16 —
parallel attention + Mamba heads in every block; sliding-window attention on
all but three global layers (first / middle / last, per the paper).
Meta tokens and cross-layer KV sharing are omitted (DESIGN.md §7).
"""
from repro.types import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32_001,
    sliding_window=1024,
    global_layers=(0, 15, 31),
    rope_theta=10_000.0,
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, chunk=128),
    tie_embeddings=True,
    source="arXiv:2411.13676",
)
