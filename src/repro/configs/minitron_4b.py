"""Minitron 4B (pruned Nemotron-4 15B). [arXiv:2407.14679]

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
"""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256_000,
    rope_theta=10_000.0,
    tie_embeddings=False,
    act="relu",               # Nemotron uses squared-relu; relu2 in models
    source="arXiv:2407.14679",
)
