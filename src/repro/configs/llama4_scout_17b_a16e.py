"""Llama-4 Scout 17B-active, 16 experts. [hf:meta-llama/Llama-4-Scout-17B-16E]

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts top-1,
plus a Llama-4-style shared expert (early-fusion multimodal in the original;
the text backbone is what is assigned).
"""
from repro.types import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=1, shared_expert=True,
                  capacity_factor=1.25, router_aux_weight=0.01),
    tie_embeddings=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
