"""Grok-1 314B. [hf:xai-org/grok-1]

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8 experts top-2.
"""
from repro.types import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32_768,
    vocab_size=131_072,
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=8, top_k=2, shared_expert=False,
                  capacity_factor=1.25, router_aux_weight=0.01),
    tie_embeddings=False,
    source="hf:xai-org/grok-1",
)
