"""InternLM2 20B. [arXiv:2403.17297]

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
"""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16_384,
    vocab_size=92_544,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="arXiv:2403.17297",
)
