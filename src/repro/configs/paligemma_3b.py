"""PaliGemma 3B language backbone. [arXiv:2407.07726]

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216 — SigLIP vision
encoder + projector are a STUB per the assignment carve-out:
``input_specs()`` provides precomputed patch embeddings (batch, 256, d_model)
prepended to the text tokens; we build the Gemma-style decoder that consumes
them.
"""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16_384,
    vocab_size=257_216,
    prefix_len=256,           # SigLIP 224px -> 256 patch tokens
    rope_theta=10_000.0,
    tie_embeddings=True,
    act="gelu",
    source="arXiv:2407.07726",
)
