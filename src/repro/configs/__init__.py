"""Config registry: 10 assigned architectures + the paper's own 3-D ResNets.

Every assigned config cites its source in ``source`` and matches the
assignment sheet exactly. ``get_config(name)`` / ``list_archs()`` are the
public API; ``SHAPES`` holds the 4 assigned input shapes.
"""
from __future__ import annotations

from repro.types import ModelConfig, ShapeConfig

from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4
from repro.configs.grok_1_314b import CONFIG as _grok1
from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless
from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.internlm2_20b import CONFIG as _internlm2
from repro.configs.minitron_4b import CONFIG as _minitron
from repro.configs.h2o_danube_3_4b import CONFIG as _danube
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.mamba2_130m import CONFIG as _mamba2
from repro.configs.paligemma_3b import CONFIG as _paligemma
from repro.configs.resnet3d import RESNET18, RESNET26, RESNET34

_REGISTRY = {
    c.name: c for c in (
        _llama4, _grok1, _seamless, _gemma3, _internlm2,
        _minitron, _danube, _hymba, _mamba2, _paligemma,
        RESNET18, RESNET26, RESNET34,
    )
}

# The 10 assigned architecture ids (order of the assignment sheet).
ASSIGNED_ARCHS = (
    "llama4-scout-17b-a16e",
    "grok-1-314b",
    "seamless-m4t-large-v2",
    "gemma3-12b",
    "internlm2-20b",
    "minitron-4b",
    "h2o-danube-3-4b",
    "hymba-1.5b",
    "mamba2-130m",
    "paligemma-3b",
)

SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    seq_len=4_096,   global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32_768,  global_batch=32,  kind="prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  seq_len=32_768,  global_batch=128, kind="decode"),
    "long_500k":   ShapeConfig("long_500k",   seq_len=524_288, global_batch=1,   kind="decode"),
}


def get_config(name: str) -> ModelConfig:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}") from None


def list_archs() -> list[str]:
    return list(ASSIGNED_ARCHS)


def shape_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is in the dry-run matrix; (ok, reason_if_not).

    Mirrors DESIGN.md's skip list: long_500k needs sub-quadratic attention.
    """
    if cfg.family == "resnet3d":
        if shape.kind != "train":
            return False, "resnet3d: clip classifier, no autoregressive decode"
        return True, ""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k decode skipped per DESIGN.md"
    return True, ""
