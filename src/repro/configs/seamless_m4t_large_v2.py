"""SeamlessM4T-Large v2 text/speech backbone. [arXiv:2308.11596]

24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206 — encoder-decoder,
multimodal. The mel-spectrogram + conformer feature frontend is a STUB per
the assignment carve-out: ``input_specs()`` provides precomputed frame
embeddings of shape (batch, src_len, d_model); we build the transformer
backbone (24 encoder + 24 decoder layers of the given width).
"""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,            # decoder layers
    num_encoder_layers=24,    # encoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
    tie_embeddings=False,
    act="relu",
    source="arXiv:2308.11596",
)
