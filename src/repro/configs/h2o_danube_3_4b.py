"""H2O-Danube3 4B. [arXiv:2401.16818 (danube series)]

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000 — llama+mistral mix
with sliding-window attention (window 4096) -> long_500k runs.
"""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10_240,
    vocab_size=32_000,
    sliding_window=4096,
    rope_theta=10_000.0,
    tie_embeddings=False,
    source="arXiv:2401.16818",
)
