"""The paper's own architectures: 3-D ResNet-18 / 26 / 34 (Hara et al. [15]).

Teacher = ResNet-34, TA = ResNet-26, student = ResNet-18, all ending in a
Kinetics-400-way classifier (equal logit width is what KD requires).
``d_model`` holds the stem width (64); ``num_layers`` the total conv depth.
Stage block counts live in BLOCKS.
"""
from repro.types import ModelConfig

# Stage block counts for the BasicBlock (2 convs / block) variants.
BLOCKS = {
    "resnet3d-18": (2, 2, 2, 2),
    "resnet3d-22": (2, 2, 3, 3),
    "resnet3d-24": (2, 3, 3, 3),
    "resnet3d-26": (3, 3, 3, 3),
    "resnet3d-28": (3, 3, 4, 3),
    "resnet3d-30": (3, 4, 4, 3),
    "resnet3d-34": (3, 4, 6, 3),
}

KINETICS_CLASSES = 400
CLIP_FRAMES = 8          # "A clip consists of 8 video frames."
CLIP_SIZE = 112          # spatial crop used by Hara et al.


def _mk(name: str) -> ModelConfig:
    depth = 2 + 2 * sum(BLOCKS[name])
    return ModelConfig(
        name=name,
        family="resnet3d",
        num_layers=depth,
        d_model=64,                  # stem width
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=KINETICS_CLASSES,  # logits width == classes
        num_classes=KINETICS_CLASSES,
        source="arXiv:1708.07632 (Hara et al.), paper §III-A",
    )


RESNET18 = _mk("resnet3d-18")
RESNET26 = _mk("resnet3d-26")
RESNET34 = _mk("resnet3d-34")
