"""Gemma-3 12B. [hf:google/gemma-3-1b-pt family card]

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144 — 5:1 local:global
attention (sliding window 1024 on local layers, every 6th layer global),
128k context in the original; long_500k runs via the SWA-dominant pattern.
"""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    d_ff=15_360,
    vocab_size=262_144,
    sliding_window=1024,
    global_every=6,           # 5 local : 1 global
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    act="gelu",
    source="hf:google/gemma-3-1b-pt",
)
