"""Mamba2 SSD chunk-scan Pallas TPU kernel.

One kernel does the whole SSD layer for a (batch·head) slice: the grid is
(BH, num_chunks) with the chunk index innermost, so the inter-chunk state
h (P, N) lives in VMEM scratch and is carried across the sequential grid
sweep — the TPU-native replacement for the GPU version's separate
intra-chunk GEMM kernel + inter-chunk scan kernel (no HBM round-trip for
the states).

Per chunk (Q = chunk length):
  dA   = dt ⊙ A                 (Q,)
  L    = exp(segsum(dA))        (Q, Q) lower-tri decay
  Yin  = ((C Bᵀ) ⊙ L) (x·dt)    intra-chunk
  Yout = (C hᵀ) ⊙ exp(cumsum dA)  inter-chunk read
  h    = exp(Σ dA) · h + Σ_q dt_q·decay_q·(x_q ⊗ B_q)

``ssd_decode_step_pallas`` is the serving-side sibling: ONE recurrent
token step, fused — the state decay ``exp(dt·A)``, the rank-1 update
``dt·x⊗B``, and the ``C`` readout run in a single VMEM-resident kernel
per stream, so the (H, P, N) state makes exactly one HBM round trip and
the update tensor is never materialized (the einsum path writes it out).
It mirrors ``models.ssm.ssm_decode_step``'s op sequence exactly, so the
fused decode is bit-identical to the einsum oracle in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.roofline.analysis import ssd_decode_bytes, ssd_decode_flops


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref,
            *, nchunks: int, Q: int):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)       # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)     # (Q,)
    A = a_ref[0].astype(jnp.float32)          # scalar
    Bm = b_ref[0, 0].astype(jnp.float32)      # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)      # (Q, N)

    dA = dt * A                               # (Q,) negative
    cum = jnp.cumsum(dA)                      # (Q,)

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j
    seg = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri, jnp.exp(seg), 0.0)
    scores = (Cm @ Bm.T) * L                  # (Q, Q)
    xdt = x * dt[:, None]
    y = scores @ xdt                          # (Q, P)

    # inter-chunk read from carried state
    h = h_ref[...]                            # (P, N)
    y += jnp.exp(cum)[:, None] * (Cm @ h.T)

    # state update
    decay_states = jnp.exp(cum[-1] - cum)     # (Q,)
    upd = (xdt * decay_states[:, None]).T @ Bm     # (P, N)
    h_ref[...] = h * jnp.exp(cum[-1]) + upd

    y_ref[0, 0, ...] = y.astype(y_ref.dtype)

    @pl.when(c_idx == nchunks - 1)
    def _done():
        hout_ref[0, ...] = h_ref[...].astype(hout_ref.dtype)


def ssd_scan_pallas(x, dt, A, Bm, Cm, chunk: int, interpret: bool = True):
    """x: (B,S,H,P), dt: (B,S,H) (softplus'ed), A: (H,), Bm/Cm: (B,S,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N)). Matches
    ref.ssd_scan_ref / models.ssm.ssd_chunked.
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    if S % Q != 0:
        raise ValueError(f"seq len {S} not divisible by chunk {Q}")
    nc = S // Q
    BH = B * H

    # fold (B, H) and broadcast the shared B/C across heads
    xf = x.transpose(0, 2, 1, 3).reshape(BH, nc, Q, P)
    dtf = dt.transpose(0, 2, 1).reshape(BH, nc, Q)
    af = jnp.broadcast_to(A[None, :], (B, H)).reshape(BH)
    bf = jnp.broadcast_to(Bm[:, None], (B, H, S, N)).reshape(BH, nc, Q, N)
    cf = jnp.broadcast_to(Cm[:, None], (B, H, S, N)).reshape(BH, nc, Q, N)

    y, hout = pl.pallas_call(
        functools.partial(_kernel, nchunks=nc, Q=Q),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, c: (b,)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, P, N), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, nc, Q, P), x.dtype),
            jax.ShapeDtypeStruct((BH, P, N), x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, bf, cf)

    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    return y, hout.reshape(B, H, P, N)


def _decode_kernel(dt_ref, a_ref, x_ref, b_ref, c_ref, h_ref,
                   y_ref, hout_ref):
    dt = dt_ref[...]                                  # (B, H) f32
    A = a_ref[...]                                    # (H,) f32
    xh = x_ref[...]                                   # (B, H, P)
    Bm = b_ref[...]                                   # (B, N)
    Cm = c_ref[...]                                   # (B, N)
    h = h_ref[...]                                    # (B, H, P, N)
    dA = jnp.exp(dt * A[None, :])
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt.astype(xh.dtype), xh, Bm)
    h_new = h * dA[..., None, None].astype(h.dtype) + upd
    hout_ref[...] = h_new.astype(hout_ref.dtype)
    y_ref[...] = jnp.einsum("bhpn,bn->bhp", h_new, Cm).astype(y_ref.dtype)


def ssd_decode_step_pallas(xh, dt, A, Bm, Cm, state, interpret: bool = True):
    """ONE fused recurrent SSD token step for the whole decode batch.

    xh: (B, H, P), dt: (B, H) f32 (softplus'ed), A: (H,) f32,
    Bm/Cm: (B, N), state: (B, H, P, N).  Returns (y (B, H, P), new_state)
    — op-for-op the ``dA / upd / state / y`` block of
    ``models.ssm.ssm_decode_step``, fused so the state makes one HBM
    round trip and ``upd`` never leaves VMEM.  dt == 0 rows are exact
    no-ops on the state (dA = 1, upd = 0), which is what makes ladder
    pad steps safe.

    The grid is a single program over the full (decode-sized) batch
    rather than one per stream: the batched einsums then trace to the
    exact dot_generals of the einsum oracle, keeping fused decode
    bit-identical (per-stream blocks change the fp32 contraction order).
    """
    B, H, P = xh.shape
    N = Bm.shape[-1]
    y_dtype = jnp.result_type(state.dtype, Cm.dtype)
    cost = {}
    if hasattr(pl, "CostEstimate"):
        cost = {"cost_estimate": pl.CostEstimate(
            flops=B * ssd_decode_flops(H, P, N),
            transcendentals=B * H,
            bytes_accessed=B * ssd_decode_bytes(
                H, P, N, dtype_bytes=jnp.dtype(state.dtype).itemsize))}
    return pl.pallas_call(
        _decode_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, P), y_dtype),
            jax.ShapeDtypeStruct((B, H, P, N), state.dtype),
        ],
        interpret=interpret, **cost,
    )(dt, A, xh, Bm, Cm, state)
