"""Jit'd public wrappers around the Pallas kernels.

On this CPU container kernels run with interpret=True (Pallas executes the
kernel body in Python, validating the exact TPU program); on a real TPU
backend set REPRO_PALLAS_INTERPRET=0 (or rely on the auto-detect) to lower
to Mosaic.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.kd_loss import kd_loss_pallas
from repro.kernels.kd_loss import kd_loss_rows as _kd_loss_rows
from repro.kernels.ssd_scan import ssd_decode_step_pallas, ssd_scan_pallas
from repro.kernels.swa_attention import (extent_decode_attend_pallas,
                                         ring_decode_attend_pallas,
                                         swa_attention_pallas)


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


# Module-level kernel leaf wrappers: one jit per op for the whole process,
# compile keys are the declared static_argnames — already the discipline
# JitCache enforces, with no donation or entry-point multiplexing to pool.
# repro-lint: disable=R1
@functools.partial(jax.jit, static_argnames=("alpha", "temperature"))
def kd_loss(student_logits, teacher_logits, labels, alpha: float,
            temperature: float = 1.0):
    """Mean fused KD loss over all rows (α·CE + (1-α)·Σ((s-t)/T)²)."""
    R = 1
    for dim in student_logits.shape[:-1]:
        R *= dim
    V = student_logits.shape[-1]
    per_row = kd_loss_pallas(student_logits.reshape(R, V),
                             teacher_logits.reshape(R, V),
                             labels.reshape(R), alpha,
                             temperature=temperature,
                             interpret=_interpret())
    return jnp.mean(per_row)


# Not jitted (like the decode-step kernels below): this is the loss leaf of
# the distillation engine's scan programs, which its JitCache compiles as a
# whole — a nested module-level jit would fragment that cache. The analytic
# custom_vjp makes it a drop-in for value_and_grad inside those programs.
def kd_loss_rows(student_logits, teacher_logits, labels, alpha: float,
                 temperature: float = 1.0, valid=None):
    """Differentiable per-row fused KD loss; (R, V) in, (R,) f32 out.

    Masked rows (``valid`` == 0) produce exactly-zero loss and gradients.
    """
    return _kd_loss_rows(student_logits, teacher_logits, labels, alpha,
                         temperature=temperature, valid=valid,
                         interpret=_interpret())


# repro-lint: disable=R1  (see kd_loss note above)
@functools.partial(jax.jit, static_argnames=("window", "causal"))
def swa_attention(q, k, v, window: int, causal: bool = True):
    """(BH, S, D) sliding-window flash attention; window=0 -> full."""
    S = q.shape[1]
    w = window if window > 0 else S
    return swa_attention_pallas(q, k, v, w, causal=causal,
                                q_block=min(128, S), k_block=min(128, S),
                                interpret=_interpret())


# repro-lint: disable=R1  (see kd_loss note above)
@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, Bm, Cm, chunk: int = 128):
    """Mamba2 SSD layer core. See ssd_scan_pallas."""
    return ssd_scan_pallas(x, dt, A, Bm, Cm, chunk,
                           interpret=_interpret())


# Decode-step kernels are NOT jitted here: they run inside the serving
# decode programs, which JitCache compiles as a whole (one program per
# ladder rung) — a nested module-level jit would fragment that cache.
def ring_decode_attend(q, k, v, pos, window):
    """Fused one-token SWA attend over a W-slot ring cache.

    q: (B, KV, G, D); k/v: (B, W, KV, D); pos/window traced int32
    scalars.  Modular slot->position mapping and window masking happen
    inside the kernel (one HBM pass over the ring)."""
    return ring_decode_attend_pallas(q, k, v, pos, window,
                                     interpret=_interpret())


def extent_decode_attend(q, k, v, pos, window, k_ext: int):
    """Fused one-token attend over the first ``k_ext`` cache positions.

    q: (B, KV, G, D); k/v: (B, S_max, KV, D); static ``k_ext`` bounds the
    HBM read via the BlockSpec — the ladder-bucketed decode program only
    streams the live prefix of the uniform cache."""
    return extent_decode_attend_pallas(q, k, v, pos, window, k_ext,
                                       interpret=_interpret())


def ssd_decode_step(xh, dt, A, Bm, Cm, state):
    """Fused one-token SSD recurrence (decay + rank-1 update + readout)."""
    return ssd_decode_step_pallas(xh, dt, A, Bm, Cm, state,
                                  interpret=_interpret())


# re-export oracles for convenience
kd_loss_ref = ref.kd_loss_ref
swa_attention_ref = ref.swa_attention_ref
ssd_scan_ref = ref.ssd_scan_ref
ssd_sequential_ref = ref.ssd_sequential_ref
