"""Fused KD-loss Pallas TPU kernel: α·CE(student, labels) + (1-α)·Σ((s-t)/T)².

Motivation (DESIGN.md §3): the KD tail is memory-bound — a naive
implementation reads the student logits for max, exp-sum, gather and the
squared error separately, and reads the teacher logits twice. This kernel
streams both logit tensors through VMEM exactly once, carrying the online
logsumexp (m, l), the gathered gold logit, and the running squared error in
VMEM scratch across vocab tiles.

Grid = (row_blocks, vocab_tiles); the vocab tile index is innermost so the
scratch accumulators live across the sweep of one row block.

Two additions serve the batched distillation engine (core/distill.py):

- ``temperature`` scales the logit-matching term to Σ((s-t)/T)² — T=1 is
  the paper's plain MSE-on-logits; extreme T exercises the accumulator's
  numerics (the parity tests sweep T→0⁺ and T≫1).
- ``valid`` is a per-row float mask: rows with valid == 0 produce *exactly*
  0.0 (a ``where``-select, never ``0·x``, so garbage rows — padding from
  the masked-scan engine — cannot leak NaN/Inf into the output).

``kd_loss_rows`` wraps the kernel in a ``jax.custom_vjp`` with the analytic
backward (Pallas kernels have no general autodiff rule), making the fused
kernel a drop-in loss for ``jax.value_and_grad`` inside the distillation
scan programs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(s_ref, t_ref, lab_ref, v_ref, out_ref,
            m_ref, l_ref, gold_ref, sq_ref,
            *, alpha: float, inv_t: float, vb: int, num_vt: int, vocab: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        gold_ref[...] = jnp.zeros_like(gold_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    s = s_ref[...].astype(jnp.float32)              # (rb, vb)
    t = t_ref[...].astype(jnp.float32)
    lab = lab_ref[...]                              # (rb,)
    rb = s.shape[0]

    # mask out padding columns of the last tile
    col = j * vb + jax.lax.broadcasted_iota(jnp.int32, (rb, vb), 1)
    valid = col < vocab
    s_m = jnp.where(valid, s, -1e30)

    # online logsumexp
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s_m, axis=-1))
    l_ref[...] = l_ref[...] * jnp.exp(m_prev - m_new) \
        + jnp.sum(jnp.where(valid, jnp.exp(s_m - m_new[:, None]), 0.0),
                  axis=-1)
    m_ref[...] = m_new

    # gold logit gather (label may fall in this tile)
    hit = col == lab[:, None]
    gold_ref[...] += jnp.sum(jnp.where(hit, s, 0.0), axis=-1)

    # running squared error (zero on padding), temperature-scaled
    diff = jnp.where(valid, (s - t) * inv_t, 0.0)
    sq_ref[...] += jnp.sum(diff * diff, axis=-1)

    @pl.when(j == num_vt - 1)
    def _done():
        ce = jnp.log(l_ref[...]) + m_ref[...] - gold_ref[...]
        loss = alpha * ce + (1.0 - alpha) * sq_ref[...]
        # select, never multiply: masked rows must be exactly 0.0 even
        # when their (garbage) logits produced NaN/Inf accumulators
        out_ref[...] = jnp.where(v_ref[...] > 0.0, loss, 0.0)


def kd_loss_pallas(student_logits, teacher_logits, labels, alpha: float,
                   temperature: float = 1.0, valid=None,
                   row_block: int = 8, vocab_block: int = 512,
                   interpret: bool = True):
    """Per-row fused loss. student/teacher: (R, V); labels (R,) int32.

    Returns (R,) float32. Rows are padded to row_block; vocab tiles are
    masked in-kernel so any (R, V) works. ``valid`` (R,) marks live rows
    (None = all live); masked rows return exactly 0.0. ``alpha`` and
    ``temperature`` are trace-time statics.
    """
    R, V = student_logits.shape
    if valid is None:
        valid = jnp.ones((R,), jnp.float32)
    valid = valid.astype(jnp.float32)
    rb = min(row_block, R)
    pad_r = (-R) % rb
    if pad_r:
        student_logits = jnp.pad(student_logits, ((0, pad_r), (0, 0)))
        teacher_logits = jnp.pad(teacher_logits, ((0, pad_r), (0, 0)))
        labels = jnp.pad(labels, (0, pad_r))
        valid = jnp.pad(valid, (0, pad_r))          # pad rows are invalid
    Rp = R + pad_r
    vb = min(vocab_block, V)
    num_vt = pl.cdiv(V, vb)
    pad_v = num_vt * vb - V
    if pad_v:
        student_logits = jnp.pad(student_logits, ((0, 0), (0, pad_v)))
        teacher_logits = jnp.pad(teacher_logits, ((0, 0), (0, pad_v)))

    # alpha/temperature are declared static at the jit boundaries that
    # wrap this call (ops.kd_loss, the distill engine's dcfg fields),
    # so these float() are trace-time constants, not device syncs.
    alpha_c = float(alpha)                # repro-lint: disable=R2
    inv_t = 1.0 / float(temperature)      # repro-lint: disable=R2
    out = pl.pallas_call(
        functools.partial(_kernel, alpha=alpha_c, inv_t=inv_t, vb=vb,
                          num_vt=num_vt, vocab=V),
        grid=(Rp // rb, num_vt),
        in_specs=[
            pl.BlockSpec((rb, vb), lambda i, j: (i, j)),
            pl.BlockSpec((rb, vb), lambda i, j: (i, j)),
            pl.BlockSpec((rb,), lambda i, j: (i,)),
            pl.BlockSpec((rb,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((rb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Rp,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((rb,), jnp.float32),   # running max m
            pltpu.VMEM((rb,), jnp.float32),   # running sumexp l
            pltpu.VMEM((rb,), jnp.float32),   # gold logit
            pltpu.VMEM((rb,), jnp.float32),   # running Σ((s-t)/T)²
        ],
        interpret=interpret,
    )(student_logits, teacher_logits, labels, valid)
    return out[:R]


# ---------------------------------------------------------------------------
# Differentiable wrapper: Pallas forward, analytic backward
# ---------------------------------------------------------------------------
#
#   L_r = α·(logsumexp(s_r) - s_r[y_r]) + (1-α)·Σ_v ((s_rv - t_rv)/T)²
#   ∂L_r/∂s = α·(softmax(s_r) - onehot(y_r)) + 2(1-α)(s_r - t_r)/T²
#   ∂L_r/∂t = -2(1-α)(s_r - t_r)/T²
#
# masked rows get exactly-zero cotangents (where-select, so garbage logits
# in padded rows cannot NaN-poison the gradients either).

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _rows_vjp(alpha, temperature, interpret, s, t, labels, valid):
    return kd_loss_pallas(s, t, labels, alpha, temperature=temperature,
                          valid=valid, interpret=interpret)


def _rows_fwd(alpha, temperature, interpret, s, t, labels, valid):
    out = _rows_vjp(alpha, temperature, interpret, s, t, labels, valid)
    return out, (s, t, labels, valid)


def _rows_bwd(alpha, temperature, interpret, res, g):
    s, t, labels, valid = res
    s32 = s.astype(jnp.float32)
    t32 = t.astype(jnp.float32)
    p = jax.nn.softmax(s32, axis=-1)
    onehot = jax.nn.one_hot(labels, s.shape[-1], dtype=jnp.float32)
    dsq = (2.0 / (temperature * temperature)) * (s32 - t32)
    live = (valid > 0.0)[:, None]
    gcol = g[:, None]
    ds = jnp.where(live, gcol * (alpha * (p - onehot)
                                 + (1.0 - alpha) * dsq), 0.0)
    dt = jnp.where(live, gcol * (-(1.0 - alpha)) * dsq, 0.0)
    return ds.astype(s.dtype), dt.astype(t.dtype), None, None


_rows_vjp.defvjp(_rows_fwd, _rows_bwd)


def kd_loss_rows(student_logits, teacher_logits, labels, alpha: float,
                 temperature: float = 1.0, valid=None,
                 interpret: bool = True):
    """Differentiable per-row fused KD loss (grad flows to both logit
    tensors; labels/valid are non-differentiable). Same shapes and masking
    semantics as ``kd_loss_pallas``."""
    R = student_logits.shape[0]
    if valid is None:
        valid = jnp.ones((R,), jnp.float32)
    return _rows_vjp(alpha, temperature, interpret,
                     student_logits, teacher_logits,
                     labels.astype(jnp.int32), valid.astype(jnp.float32))
