"""Fused KD-loss Pallas TPU kernel: α·CE(student, labels) + (1-α)·Σ(s-t)².

Motivation (DESIGN.md §3): the KD tail is memory-bound — a naive
implementation reads the student logits for max, exp-sum, gather and the
squared error separately, and reads the teacher logits twice. This kernel
streams both logit tensors through VMEM exactly once, carrying the online
logsumexp (m, l), the gathered gold logit, and the running squared error in
VMEM scratch across vocab tiles.

Grid = (row_blocks, vocab_tiles); the vocab tile index is innermost so the
scratch accumulators live across the sweep of one row block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(s_ref, t_ref, lab_ref, out_ref,
            m_ref, l_ref, gold_ref, sq_ref,
            *, alpha: float, vb: int, num_vt: int, vocab: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        gold_ref[...] = jnp.zeros_like(gold_ref)
        sq_ref[...] = jnp.zeros_like(sq_ref)

    s = s_ref[...].astype(jnp.float32)              # (rb, vb)
    t = t_ref[...].astype(jnp.float32)
    lab = lab_ref[...]                              # (rb,)
    rb = s.shape[0]

    # mask out padding columns of the last tile
    col = j * vb + jax.lax.broadcasted_iota(jnp.int32, (rb, vb), 1)
    valid = col < vocab
    s_m = jnp.where(valid, s, -1e30)

    # online logsumexp
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s_m, axis=-1))
    l_ref[...] = l_ref[...] * jnp.exp(m_prev - m_new) \
        + jnp.sum(jnp.where(valid, jnp.exp(s_m - m_new[:, None]), 0.0),
                  axis=-1)
    m_ref[...] = m_new

    # gold logit gather (label may fall in this tile)
    hit = col == lab[:, None]
    gold_ref[...] += jnp.sum(jnp.where(hit, s, 0.0), axis=-1)

    # running squared error (zero on padding)
    diff = jnp.where(valid, s - t, 0.0)
    sq_ref[...] += jnp.sum(diff * diff, axis=-1)

    @pl.when(j == num_vt - 1)
    def _done():
        ce = jnp.log(l_ref[...]) + m_ref[...] - gold_ref[...]
        out_ref[...] = alpha * ce + (1.0 - alpha) * sq_ref[...]


def kd_loss_pallas(student_logits, teacher_logits, labels, alpha: float,
                   row_block: int = 8, vocab_block: int = 512,
                   interpret: bool = True):
    """Per-row fused loss. student/teacher: (R, V); labels (R,) int32.

    Returns (R,) float32. Rows are padded to row_block; vocab tiles are
    masked in-kernel so any (R, V) works.
    """
    R, V = student_logits.shape
    rb = min(row_block, R)
    pad_r = (-R) % rb
    if pad_r:
        student_logits = jnp.pad(student_logits, ((0, pad_r), (0, 0)))
        teacher_logits = jnp.pad(teacher_logits, ((0, pad_r), (0, 0)))
        labels = jnp.pad(labels, (0, pad_r))
    Rp = R + pad_r
    vb = min(vocab_block, V)
    num_vt = pl.cdiv(V, vb)
    pad_v = num_vt * vb - V
    if pad_v:
        student_logits = jnp.pad(student_logits, ((0, 0), (0, pad_v)))
        teacher_logits = jnp.pad(teacher_logits, ((0, 0), (0, pad_v)))

    out = pl.pallas_call(
        # alpha is declared static at the ops.kd_loss jit boundary, so this
        # float() is a trace-time constant, not a device sync.
        # repro-lint: disable=R2
        functools.partial(_kernel, alpha=float(alpha), vb=vb,
                          num_vt=num_vt, vocab=V),
        grid=(Rp // rb, num_vt),
        in_specs=[
            pl.BlockSpec((rb, vb), lambda i, j: (i, j)),
            pl.BlockSpec((rb, vb), lambda i, j: (i, j)),
            pl.BlockSpec((rb,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((rb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Rp,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((rb,), jnp.float32),   # running max m
            pltpu.VMEM((rb,), jnp.float32),   # running sumexp l
            pltpu.VMEM((rb,), jnp.float32),   # gold logit
            pltpu.VMEM((rb,), jnp.float32),   # running Σ(s-t)²
        ],
        interpret=interpret,
    )(student_logits, teacher_logits, labels)
    return out[:R]
