"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kd_loss_ref(student_logits, teacher_logits, labels, alpha: float,
                temperature: float = 1.0, valid=None):
    """Per-row fused KD loss: α·CE + (1-α)·Σ((s-t)/T)². Rows = flattened
    batch; T=1 is the paper's plain MSE-on-logits.

    student/teacher: (R, V); labels: (R,) int32. Returns (R,) float32.
    Rows where ``valid`` == 0 return exactly 0.0 (select, not multiply,
    so garbage logits in masked rows cannot leak NaN/Inf).
    """
    s = student_logits.astype(jnp.float32)
    t = teacher_logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(s, axis=-1)
    gold = jnp.take_along_axis(s, labels[:, None], axis=-1)[:, 0]
    ce = lse - gold
    d = (s - t) / temperature
    sq = jnp.sum(d * d, axis=-1)
    out = alpha * ce + (1.0 - alpha) * sq
    if valid is None:
        return out
    return jnp.where(valid.astype(jnp.float32) > 0.0, out, 0.0)


def swa_attention_ref(q, k, v, window: int, causal: bool = True):
    """Sliding-window attention oracle. q,k,v: (BH, S, D); window>0 = #keys
    each query may see (its own position included). Returns (BH, S, D)."""
    BH, S, D = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    ok = (qi - ki < window) & (qi - ki >= 0) if causal else \
        (jnp.abs(qi - ki) < window)
    s = jnp.where(ok[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_ref(x, dt, A, Bm, Cm, chunk: int):
    """Mamba2 SSD oracle — delegates to the model's chunked implementation
    (itself validated against a naive sequential recurrence in tests).

    x: (B,S,H,P), dt: (B,S,H) (already softplus'ed), A: (H,),
    Bm/Cm: (B,S,N). Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, A, Bm, Cm, chunk)


def ssd_sequential_ref(x, dt, A, Bm, Cm):
    """Naive O(S) recurrence — the *independent* ground truth for SSD.

    h_t = exp(dt_t A) h_{t-1} + dt_t · x_t ⊗ B_t ;  y_t = C_t · h_t
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp
        dA = jnp.exp(dtt * A[None, :])                       # (B,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, bt)
        h = h * dA[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h.astype(x.dtype)
