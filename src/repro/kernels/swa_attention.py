"""Sliding-window flash attention Pallas TPU kernel.

TPU adaptation of the paper-adjacent GPU flash pattern: online-softmax
accumulation over KV tiles with *structural* block skipping — for window w
and query block qi, only ceil((w + qb)/kb) + 1 KV tiles can intersect the
band, so the grid's KV dimension is that count and the BlockSpec index_map
selects which physical tile each grid step loads (clamped at the sequence
edges; out-of-band positions are masked in-kernel using the recomputed
physical tile index). Full attention is the same kernel with w = S.

Layout: q, k, v are (BH, S, D) — heads pre-folded, GQA expansion done in
ops.py. MXU-aligned D (64/128/256); block sizes default 128.

This module also holds the two *decode*-side kernels serving's ring/ladder
hot path fuses into (one grid step per stream, the whole step in VMEM):

``ring_decode_attend_pallas``
    One-token attend against a W-slot ring cache.  The modular-slot
    masking runs *inside* the kernel: slot ``s`` holds the latest absolute
    position ``p ≡ s (mod W)``, so ``k_pos = pos - mod(pos - s, W)`` is
    recomputed from the traced ``pos`` scalar (SMEM) and negative /
    out-of-window slots are masked — one HBM pass over the W slots,
    no gathered position vector, no score round-trip.

``extent_decode_attend_pallas``
    One-token attend for ladder-bucketed full attention: the static
    ``k_ext`` is a *kernel parameter* (the BlockSpec reads only the first
    ``k_ext`` cache positions — the ladder rung, not ``S_max``) and the
    per-stream ``k_len = pos + 1`` mask is applied in-kernel from the
    traced position.

Both mirror ``models.attention.gqa_attention``'s einsum/softmax ops
exactly (same dot shapes, same additive -1e30 bias, same divide-after-sum
softmax), so the fused decode is bit-identical to the einsum oracle in
interpret mode — the serving parity tests assert token equality.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.roofline.analysis import attend_decode_bytes, attend_decode_flops

NEG_INF = -1e30


def _kv_block_index(qi, kj, *, qb, kb, nkv_grid, nk_max):
    """Physical KV tile for grid step (qi, kj): the last needed tile is the
    one containing this q block's end; earlier grid steps walk back."""
    last = (qi * qb + qb - 1) // kb
    idx = last - (nkv_grid - 1) + kj
    return jnp.clip(idx, 0, nk_max - 1)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, qb, kb, window, causal, nkv_grid, nk_max, seq_len, scale):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                 # (qb, D)
    k = k_ref[0].astype(jnp.float32)                 # (kb, D)
    v = v_ref[0].astype(jnp.float32)

    s = (q @ k.T) * scale                            # (qb, kb)

    # positions from the *physical* tile this grid step loaded
    blk = _kv_block_index(qi, kj, qb=qb, kb=kb, nkv_grid=nkv_grid,
                          nk_max=nk_max)
    q_pos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
    k_pos = blk * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
    d = q_pos - k_pos
    ok = (d < window) & (k_pos < seq_len)
    if causal:
        ok &= d >= 0
    else:
        ok &= d > -window
    # duplicate-tile guard: edge clamping makes early grid steps re-load
    # physical tile 0; only the unclamped owner contributes (own == blk).
    last = (qi * qb + qb - 1) // kb
    own = last - (nkv_grid - 1) + kj
    ok &= own == blk

    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(ok, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(kj == nkv_grid - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# Decode-side kernels (one query token per stream, serving hot path)
# ---------------------------------------------------------------------------

def _decode_attend(q, k, v, bias, scale, out_dtype):
    """Shared one-token attend body: the exact op sequence of
    ``models.attention.gqa_attention``'s attend() closure (f32 score
    einsum, additive bias, max-subtract/divide softmax, f32 p·V) so the
    fused kernels stay bit-identical to the einsum oracle."""
    s = jnp.einsum("kgd,skd->kgs", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = s + bias[None, None, :]
    m = jnp.max(s, axis=-1, keepdims=True)
    un = jnp.exp(s - jax.lax.stop_gradient(m))
    p = (un / jnp.sum(un, axis=-1, keepdims=True)).astype(q.dtype)
    return jnp.einsum("kgs,skd->kgd", p, v,
                      preferred_element_type=jnp.float32).astype(out_dtype)


def _window_bias(pos, w, k_pos):
    """Additive mask mirroring ``models.attention._mask_bias`` for a
    single query at absolute position ``pos``: causal, in-window
    (w == 0 -> full), and unwritten (k_pos < 0) slots masked."""
    w_eff = jnp.where(w == 0, jnp.int32(2 ** 30), w)
    ok = (pos >= k_pos) & (pos - k_pos < w_eff) & (k_pos >= 0)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _ring_decode_kernel(pos_ref, win_ref, q_ref, k_ref, v_ref, o_ref,
                        *, W, scale):
    pos = pos_ref[0]
    # modular-slot masking inside the kernel: slot s holds the latest
    # absolute position p <= pos with p ≡ s (mod W); negative = unwritten
    k_pos = pos - jnp.mod(pos - jax.lax.iota(jnp.int32, W), W)
    bias = _window_bias(pos, win_ref[0], k_pos)
    o_ref[0, ...] = _decode_attend(q_ref[0], k_ref[0], v_ref[0], bias,
                                   scale, o_ref.dtype)


def _cost_kwargs(n_streams, n_ctx, kv, G, D, dtype):
    if not hasattr(pl, "CostEstimate"):    # older jax: skip the annotation
        return {}
    H = kv * G
    return {"cost_estimate": pl.CostEstimate(
        flops=n_streams * attend_decode_flops(n_ctx, H, D),
        transcendentals=n_streams * H * n_ctx,
        bytes_accessed=n_streams * attend_decode_bytes(
            n_ctx, kv, H, D, dtype_bytes=jnp.dtype(dtype).itemsize))}


def ring_decode_attend_pallas(q, k, v, pos, window, interpret: bool = True):
    """One-token ring-buffer SWA decode attend.

    q: (B, KV, G, D) — the single query token, grouped heads;
    k, v: (B, W, KV, D) ring caches (slot s = latest position ≡ s mod W,
    the new token already written at slot ``pos % W``); ``pos`` /
    ``window`` int32 scalars (python ints or traced — they ride in SMEM,
    so one program serves every step). Returns (B, KV, G, D).
    """
    B, KV, G, D = q.shape
    W = k.shape[1]
    pos = jnp.reshape(jnp.asarray(pos, jnp.int32), (1,))
    win = jnp.reshape(jnp.asarray(window, jnp.int32), (1,))
    return pl.pallas_call(
        functools.partial(_ring_decode_kernel, W=W, scale=D ** -0.5),
        grid=(B,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, KV, G, D), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, W, KV, D), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, W, KV, D), lambda b: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, KV, G, D), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
        **_cost_kwargs(B, W, KV, G, D, k.dtype),
    )(pos, win, q, k, v)


def _extent_decode_kernel(pos_ref, win_ref, q_ref, k_ref, v_ref, o_ref,
                          *, k_ext, scale):
    pos = pos_ref[0]
    k_pos = jax.lax.iota(jnp.int32, k_ext)
    bias = _window_bias(pos, win_ref[0], k_pos)
    # per-stream k_len mask (cache positions beyond the active prefix),
    # mirroring attn_forward's additive k_len term exactly
    bias = bias + jnp.where(k_pos < pos + 1, 0.0, NEG_INF).astype(
        jnp.float32)
    o_ref[0, ...] = _decode_attend(q_ref[0], k_ref[0], v_ref[0], bias,
                                   scale, o_ref.dtype)


def extent_decode_attend_pallas(q, k, v, pos, window, k_ext: int,
                                interpret: bool = True):
    """One-token ladder-bucketed full-attention decode attend.

    q: (B, KV, G, D); k, v: (B, S_max, KV, D) uniform caches (the new
    token already written at position ``pos``).  ``k_ext`` (static — one
    program per ladder rung) bounds the read: the BlockSpec loads only
    the first ``k_ext`` cache positions, so the kernel's HBM traffic is
    O(k_ext) however large the cache.  Requires ``pos < k_ext`` (the
    serving ladder guarantees ``k_ext >= max(pos) + 1``); positions in
    ``[pos + 1, k_ext)`` are masked in-kernel.  Returns (B, KV, G, D).
    """
    B, KV, G, D = q.shape
    S_max = k.shape[1]
    if not 1 <= k_ext <= S_max:
        raise ValueError(f"k_ext {k_ext} out of range [1, {S_max}]")
    pos = jnp.reshape(jnp.asarray(pos, jnp.int32), (1,))
    win = jnp.reshape(jnp.asarray(window, jnp.int32), (1,))
    return pl.pallas_call(
        functools.partial(_extent_decode_kernel, k_ext=k_ext,
                          scale=D ** -0.5),
        grid=(B,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, KV, G, D), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, k_ext, KV, D), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((1, k_ext, KV, D), lambda b: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, KV, G, D), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
        **_cost_kwargs(B, k_ext, KV, G, D, k.dtype),
    )(pos, win, q, k, v)


def swa_attention_pallas(q, k, v, window: int, causal: bool = True,
                         q_block: int = 128, k_block: int = 128,
                         interpret: bool = True):
    """q,k,v: (BH, S, D) -> (BH, S, D). window>0; use window=S for full."""
    BH, S, D = q.shape
    qb = min(q_block, S)
    kb = min(k_block, S)
    if S % qb != 0 or S % kb != 0:
        raise ValueError(
            f"seq len {S} not divisible by blocks (qb={qb}, kb={kb})")
    nk_max = S // kb
    nkv_grid = min(nk_max, (window + qb - 1) // kb + 1 + (0 if causal else
                                                          (window - 1) // kb + 1))

    grid = (BH, S // qb, nkv_grid)
    kv_map = functools.partial(_kv_block_index, qb=qb, kb=kb,
                               nkv_grid=nkv_grid, nk_max=nk_max)
    out = pl.pallas_call(
        functools.partial(_kernel, qb=qb, kb=kb, window=window,
                          causal=causal, nkv_grid=nkv_grid, nk_max=nk_max,
                          seq_len=S, scale=D ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qb, D), lambda b, qi, kj: (b, qi, 0)),
            pl.BlockSpec((1, kb, D),
                         lambda b, qi, kj: (b, kv_map(qi, kj), 0)),
            pl.BlockSpec((1, kb, D),
                         lambda b, qi, kj: (b, kv_map(qi, kj), 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, D), lambda b, qi, kj: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
