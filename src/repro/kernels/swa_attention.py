"""Sliding-window flash attention Pallas TPU kernel.

TPU adaptation of the paper-adjacent GPU flash pattern: online-softmax
accumulation over KV tiles with *structural* block skipping — for window w
and query block qi, only ceil((w + qb)/kb) + 1 KV tiles can intersect the
band, so the grid's KV dimension is that count and the BlockSpec index_map
selects which physical tile each grid step loads (clamped at the sequence
edges; out-of-band positions are masked in-kernel using the recomputed
physical tile index). Full attention is the same kernel with w = S.

Layout: q, k, v are (BH, S, D) — heads pre-folded, GQA expansion done in
ops.py. MXU-aligned D (64/128/256); block sizes default 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kv_block_index(qi, kj, *, qb, kb, nkv_grid, nk_max):
    """Physical KV tile for grid step (qi, kj): the last needed tile is the
    one containing this q block's end; earlier grid steps walk back."""
    last = (qi * qb + qb - 1) // kb
    idx = last - (nkv_grid - 1) + kj
    return jnp.clip(idx, 0, nk_max - 1)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, qb, kb, window, causal, nkv_grid, nk_max, seq_len, scale):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                 # (qb, D)
    k = k_ref[0].astype(jnp.float32)                 # (kb, D)
    v = v_ref[0].astype(jnp.float32)

    s = (q @ k.T) * scale                            # (qb, kb)

    # positions from the *physical* tile this grid step loaded
    blk = _kv_block_index(qi, kj, qb=qb, kb=kb, nkv_grid=nkv_grid,
                          nk_max=nk_max)
    q_pos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
    k_pos = blk * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
    d = q_pos - k_pos
    ok = (d < window) & (k_pos < seq_len)
    if causal:
        ok &= d >= 0
    else:
        ok &= d > -window
    # duplicate-tile guard: edge clamping makes early grid steps re-load
    # physical tile 0; only the unclamped owner contributes (own == blk).
    last = (qi * qb + qb - 1) // kb
    own = last - (nkv_grid - 1) + kj
    ok &= own == blk

    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(ok, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(kj == nkv_grid - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def swa_attention_pallas(q, k, v, window: int, causal: bool = True,
                         q_block: int = 128, k_block: int = 128,
                         interpret: bool = True):
    """q,k,v: (BH, S, D) -> (BH, S, D). window>0; use window=S for full."""
    BH, S, D = q.shape
    qb = min(q_block, S)
    kb = min(k_block, S)
    if S % qb != 0 or S % kb != 0:
        raise ValueError(
            f"seq len {S} not divisible by blocks (qb={qb}, kb={kb})")
    nk_max = S // kb
    nkv_grid = min(nk_max, (window + qb - 1) // kb + 1 + (0 if causal else
                                                          (window - 1) // kb + 1))

    grid = (BH, S // qb, nkv_grid)
    kv_map = functools.partial(_kv_block_index, qb=qb, kb=kb,
                               nkv_grid=nkv_grid, nk_max=nk_max)
    out = pl.pallas_call(
        functools.partial(_kernel, qb=qb, kb=kb, window=window,
                          causal=causal, nkv_grid=nkv_grid, nk_max=nk_max,
                          seq_len=S, scale=D ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qb, D), lambda b, qi, kj: (b, qi, 0)),
            pl.BlockSpec((1, kb, D),
                         lambda b, qi, kj: (b, kv_map(qi, kj), 0)),
            pl.BlockSpec((1, kb, D),
                         lambda b, qi, kj: (b, kv_map(qi, kj), 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, D), lambda b, qi, kj: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
