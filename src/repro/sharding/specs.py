"""PartitionSpec rules for every architecture on the production meshes.

Axes: ('data', 'model') single-pod; ('pod', 'data', 'model') multi-pod.
Training batches shard over (pod, data); model weights shard over 'model'
(tensor/expert parallelism); optimizer state follows its parameter.

Every rule is divisibility-guarded: a dim is sharded only when the mesh
axis divides it, otherwise that dim replicates — this is what lets one
rule set cover head counts of 25 (hymba), 8-expert MoE on a 16-way model
axis (falls back to d_ff tensor parallelism), vocab 50280, etc.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.types import ModelConfig, ShapeConfig


def shard_map(f, mesh: Mesh, in_specs, out_specs,
              check_replication: bool = True):
    """Version-portable ``shard_map``.

    Newer jax exposes ``jax.shard_map`` (replication checking spelled
    ``check_vma``); 0.4.x only ships ``jax.experimental.shard_map``
    (spelled ``check_rep``). Both the MoE distributed dispatch
    (models/moe.py) and the sharded federated sync round
    (core/fed_engine.py) go through this wrapper so they run on either.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             check_vma=check_replication)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_replication)


def fed_round_specs(mesh: Mesh) -> dict:
    """PartitionSpecs for the shard_map'ed federated sync round.

    The round has exactly two kinds of operands: per-client arrays with a
    leading client axis (batch stacks (n, H_max, ...), weights (n,), the
    H^k iteration vector (n,), per-client losses (n, H)) which shard over
    the mesh's client axis, and fleet-global arrays (params, trainable
    mask, the psum'ed new global) which replicate. Specs are pytree
    prefixes: ``P(axis)`` shards only the leading dim of every leaf.

    On the hierarchical ``('edge', 'clients')`` mesh
    (``launch.mesh.make_fleet_mesh(edges=...)``) the leading client dim
    shards over BOTH axes — shard (e, c) holds the clients of edge
    aggregator e's c-th slot — and ``axis`` is the ``('edge', 'clients')``
    tuple, outermost first, so the round can reduce level by level
    (clients → edge, edge → server).
    """
    if {"edge", "clients"} <= set(mesh.axis_names):
        axis = ("edge", "clients")
        return {"axis": axis, "clients": P(axis), "replicated": P()}
    axis = "clients" if "clients" in mesh.axis_names else mesh.axis_names[0]
    return {"axis": axis, "clients": P(axis), "replicated": P()}


def data_axes(mesh: Mesh):
    """The batch-parallel axes present in a mesh."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        s = 1
        for n in name:
            s *= _axis_size(mesh, n)
        return s
    return mesh.shape[name] if name in mesh.axis_names else 0


def _maybe(mesh: Mesh, axis, dim: int):
    """axis if it divides dim (and exists), else None."""
    size = _axis_size(mesh, axis)
    if size and dim % size == 0:
        return axis
    return None


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _spec_for(mesh: Mesh, path: tuple, leaf, fsdp: bool = True) -> P:
    """Rule table keyed by the param's path inside the pytree.

    Two-level weight sharding: the "tensor parallel" dim shards over
    'model'; with ``fsdp`` the other large dim additionally shards over
    ('pod','data') (ZeRO-3 style), which is what lets grok-1's 314B fit —
    weights replicated across the data axis would be 39 GiB/chip.
    """
    keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    name = keys[-1]
    shape = leaf.shape
    m = lambda dim: _maybe(mesh, "model", dim)  # noqa: E731
    dp_axes = data_axes(mesh)
    dp_flat = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes
                                                else None)

    def d(dim):
        if not fsdp or dp_flat is None:
            return None
        return _maybe(mesh, dp_flat, dim)

    # ---- embeddings / heads ----
    if name == "embed":
        return P(m(shape[0]), d(shape[1]))
    if name == "lm_head":
        return P(d(shape[0]), m(shape[1]))

    stacked = "layers" in keys or "enc_layers" in keys or "dec_layers" in keys
    off = 1 if stacked else 0  # leading L axis on scanned stacks

    def lead(*rest):
        return P(*(((None,) * off) + rest))

    # ---- attention ----
    if len(keys) >= 2 and keys[-2] in ("attn", "xattn"):
        if name in ("wq", "wk", "wv"):
            return lead(d(shape[-2]), m(shape[-1]))
        if name == "wo":
            return lead(m(shape[-2]), d(shape[-1]))

    # ---- dense / shared-expert MLP ----
    if name in ("wg", "wi", "shared_wg", "shared_wi") \
            and len(shape) == 2 + off:
        return lead(d(shape[-2]), m(shape[-1]))
    if name in ("wo", "shared_wo") and len(shape) == 2 + off:
        return lead(m(shape[-2]), d(shape[-1]))

    # ---- MoE experts: expert-parallel when E divides, else 2-D tensor ----
    if name in ("wg", "wi") and len(shape) == 3 + off:
        e = m(shape[off])
        if e is not None:
            return lead(e, d(shape[-2]), None)
        return lead(None, d(shape[-2]), m(shape[-1]))
    if name == "wo" and len(shape) == 3 + off:
        e = m(shape[off])
        if e is not None:
            return lead(e, None, d(shape[-1]))
        return lead(None, m(shape[-2]), d(shape[-1]))
    if name == "router":
        return lead(None, None)

    # ---- SSM ----
    if name == "in_proj":
        return lead(d(shape[-2]), m(shape[-1]))
    if name == "out_proj":
        return lead(m(shape[-2]), d(shape[-1]))

    # ---- everything else (norms, convs, biases, resnet) replicates ----
    return P()


def param_pspecs(mesh: Mesh, cfg: ModelConfig, params: Any,
                 fsdp: bool = True):
    """Pytree of PartitionSpec matching ``params`` (shapes or arrays)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [_spec_for(mesh, path, leaf, fsdp=fsdp) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Batches
# ---------------------------------------------------------------------------

def batch_pspecs(mesh: Mesh, cfg: ModelConfig, batch: Any):
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def spec(path, leaf):
        # batch dim shards over (pod, data) when divisible; everything else
        # replicates (feature dims of embedding inputs stay unsharded).
        lead = dp if leaf.shape[0] % max(dp_size, 1) == 0 else None
        return P(*((lead,) + (None,) * (leaf.ndim - 1)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


def cache_pspecs(mesh: Mesh, cfg: ModelConfig, cache: Any,
                 global_batch: int):
    """Serving cache sharding.

    Batched decode: batch dim over ('pod','data'). Single-sequence long
    context (batch 1): shard the cache *sequence* dim over 'data' — the
    attention contraction then reduces over 'data' (flash-decoding style);
    SSM states replicate over 'data' (they are tiny).
    """
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    dp_size = 1
    for a in data_axes(mesh):
        dp_size *= mesh.shape[a]
    batch_sharded = global_batch % max(dp_size, 1) == 0 and global_batch > 1

    def spec(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        # leading dim is L (stacked layers) — never sharded
        if name in ("k_win", "v_win"):
            # ring buffers: tiny seq dim (=window); batch over data only
            if batch_sharded:
                return P(None, dp, None, None, None)
            return P(None, None, None, None, None)
        if name in ("k", "v", "enc_k", "enc_v"):
            # (L, B, S, KV, hd)
            if batch_sharded:
                return P(None, dp, _maybe(mesh, "model", leaf.shape[2]),
                         None, None)
            return P(None, None, _maybe(mesh, ("data", "model"),
                                        leaf.shape[2]) or
                     _maybe(mesh, "data", leaf.shape[2]), None, None)
        if name == "ssm_state":
            # (L, B, H, P, N)
            if batch_sharded:
                return P(None, dp, _maybe(mesh, "model", leaf.shape[2]),
                         None, None)
            return P(None, None, _maybe(mesh, "model", leaf.shape[2]),
                     None, None)
        if name == "conv_state":
            if batch_sharded:
                return P(None, dp, None, None)
            return P(None, None, None, _maybe(mesh, "model", leaf.shape[3]))
        raise ValueError(f"unknown cache leaf {name}")

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


def token_pspec(mesh: Mesh, global_batch: int):
    dp = data_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    dp_size = 1
    for a in data_axes(mesh):
        dp_size *= mesh.shape[a]
    if global_batch % max(dp_size, 1) == 0 and global_batch > 1:
        return P(dp)
    return P(None)


def named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
