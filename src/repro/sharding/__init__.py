from repro.sharding.specs import (batch_pspecs, cache_pspecs, data_axes,
                                  named, param_pspecs, token_pspec)

__all__ = ["param_pspecs", "batch_pspecs", "cache_pspecs", "data_axes",
           "named", "token_pspec"]
