"""Communication-efficient client updates (paper §II cites [44-46]:
FedPAQ-style quantized periodic averaging).

Clients send *delta* updates Δ = w_new − w_t quantized to int8 (or packed
int4) with a per-leaf symmetric scale; the server reconstructs
w_new ≈ w_t + deq(Δ).  On the paper's testbed the model upload rides
constrained links (Table II's sync barrier is partly upload contention) —
4×/8× smaller updates shrink exactly the term the async design hides.

int4 packs two signed values per byte (``pack_int4``/``unpack_int4``);
values quantize to [-7, 7] so the nibble 0x8 (-8) is never produced and
the symmetric error bound |Δ - deq(q)| ≤ scale/2 holds for both widths.
Masked-submodel and low-rank factor payloads (``core/algorithms.py``)
ride the same per-leaf codec — that is the wire-size knob the ROADMAP
calls out for embedded-device fleets.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

# per-width quantization range: symmetric, excludes int4's -8 so the
# codec never emits a value whose negation is unrepresentable
_QMAX = {8: 127, 4: 7}


class QuantizedUpdate(NamedTuple):
    q: Any        # int8 pytree (int4 payloads kept unpacked for compute)
    scale: Any    # f32 scalar per leaf
    base_bytes: int
    wire_bytes: int
    bits: int = 8


def packed_nbytes(size: int, bits: int) -> int:
    """Payload bytes for ``size`` quantized values at the given width."""
    if bits == 8:
        return size
    return (size + 1) // 2


def pack_int4(q):
    """Pack an int8 array of values in [-7, 7] into a uint8 array, two
    nibbles per byte (low nibble first; odd tails pad with 0)."""
    flat = np.asarray(q, dtype=np.int8).reshape(-1)
    if flat.size % 2:
        flat = np.concatenate([flat, np.zeros(1, np.int8)])
    u = (flat.astype(np.int16) & 0xF).astype(np.uint8)
    return (u[0::2] | (u[1::2] << 4)).astype(np.uint8)


def unpack_int4(packed, size: int):
    """Inverse of ``pack_int4``: uint8 nibbles back to int8, trimmed to
    ``size`` values (sign-extended from 4 bits)."""
    p = np.asarray(packed, dtype=np.uint8)
    lo = (p & 0xF).astype(np.int8)
    hi = (p >> 4).astype(np.int8)
    vals = np.empty(p.size * 2, np.int8)
    vals[0::2] = lo
    vals[1::2] = hi
    vals = np.where(vals >= 8, vals - 16, vals).astype(np.int8)
    return vals[:size]


def quantize_delta(w_new, anchor, bits: int = 8) -> QuantizedUpdate:
    """Symmetric per-leaf quantization of (w_new - anchor)."""
    if bits not in _QMAX:
        raise ValueError(
            f"unsupported wire width bits={bits!r}; valid: "
            f"{sorted(_QMAX)} (int8, packed int4)")
    qmax = _QMAX[bits]

    def q_leaf(a, b):
        d = (a.astype(jnp.float32) - b.astype(jnp.float32))
        scale = jnp.maximum(jnp.max(jnp.abs(d)), 1e-12) / qmax
        q = jnp.clip(jnp.round(d / scale), -qmax, qmax).astype(jnp.int8)
        return q, scale

    flat, treedef = jax.tree_util.tree_flatten(w_new)
    anchors = jax.tree_util.tree_leaves(anchor)
    qs, scales = [], []
    base = wire = 0
    for a, b in zip(flat, anchors):
        q, s = q_leaf(a, b)
        qs.append(q)
        scales.append(s)
        base += a.size * a.dtype.itemsize
        wire += packed_nbytes(a.size, bits) + 4
    return QuantizedUpdate(jax.tree_util.tree_unflatten(treedef, qs),
                           jax.tree_util.tree_unflatten(treedef, scales),
                           base, wire, bits)


def dequantize_delta(upd: QuantizedUpdate, anchor):
    """Server-side reconstruction w_new ≈ anchor + scale·q."""
    return jax.tree_util.tree_map(
        lambda q, s, b: (b.astype(jnp.float32)
                         + q.astype(jnp.float32) * s).astype(b.dtype),
        upd.q, upd.scale, anchor)


def roundtrip(w_new, anchor, bits: int = 8):
    """Convenience: quantize + dequantize (what the server sees)."""
    upd = quantize_delta(w_new, anchor, bits)
    return dequantize_delta(upd, anchor), upd


def compression_ratio(upd: QuantizedUpdate) -> float:
    return upd.base_bytes / max(upd.wire_bytes, 1)
