"""Communication-efficient client updates (paper §II cites [44-46]:
FedPAQ-style quantized periodic averaging).

Clients send *delta* updates Δ = w_new − w_t quantized to int8 with a
per-leaf symmetric scale; the server reconstructs w_new ≈ w_t + deq(Δ).
On the paper's testbed the model upload rides constrained links (Table II's
sync barrier is partly upload contention) — 4× smaller updates shrink
exactly the term the async design hides.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp


class QuantizedUpdate(NamedTuple):
    q: Any        # int8 pytree
    scale: Any    # f32 scalar per leaf
    base_bytes: int
    wire_bytes: int


def quantize_delta(w_new, anchor, bits: int = 8) -> QuantizedUpdate:
    """Symmetric per-leaf quantization of (w_new - anchor)."""
    if bits != 8:
        raise ValueError(f"int8 wire format only (bits={bits})")

    def q_leaf(a, b):
        d = (a.astype(jnp.float32) - b.astype(jnp.float32))
        scale = jnp.maximum(jnp.max(jnp.abs(d)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(d / scale), -127, 127).astype(jnp.int8)
        return q, scale

    flat, treedef = jax.tree_util.tree_flatten(w_new)
    anchors = jax.tree_util.tree_leaves(anchor)
    qs, scales = [], []
    base = wire = 0
    for a, b in zip(flat, anchors):
        q, s = q_leaf(a, b)
        qs.append(q)
        scales.append(s)
        base += a.size * a.dtype.itemsize
        wire += q.size * 1 + 4
    return QuantizedUpdate(jax.tree_util.tree_unflatten(treedef, qs),
                           jax.tree_util.tree_unflatten(treedef, scales),
                           base, wire)


def dequantize_delta(upd: QuantizedUpdate, anchor):
    """Server-side reconstruction w_new ≈ anchor + scale·q."""
    return jax.tree_util.tree_map(
        lambda q, s, b: (b.astype(jnp.float32)
                         + q.astype(jnp.float32) * s).astype(b.dtype),
        upd.q, upd.scale, anchor)


def roundtrip(w_new, anchor, bits: int = 8):
    """Convenience: quantize + dequantize (what the server sees)."""
    upd = quantize_delta(w_new, anchor, bits)
    return dequantize_delta(upd, anchor), upd


def compression_ratio(upd: QuantizedUpdate) -> float:
    return upd.base_bytes / max(upd.wire_bytes, 1)
