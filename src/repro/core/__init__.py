# The paper's primary contribution: teacher->TA->student knowledge
# distillation (distill.py) + asynchronous federated optimization with
# staleness-adaptive mixing (fedasync.py), the synchronous FedAvg baseline
# (fedavg.py), the heterogeneous-fleet event simulator (simulator.py) with
# its streaming million-client fleet layer (fleet.py) and the
# convergence-bound evaluator (convergence.py).
from repro.core import (convergence, distill, fed_engine, fedasync, fedavg,
                        fleet, simulator)

__all__ = ["distill", "fed_engine", "fedasync", "fedavg", "fleet",
           "simulator", "convergence"]
