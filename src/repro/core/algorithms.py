"""Pluggable federated-algorithm layer: the four things the engines used
to hardcode, extracted behind one interface.

``core/fed_engine.py`` compiles *execution* — scans, padded masked scans,
vmap rounds, shard_map reductions. What used to be welded into those
programs is the *algorithm*: the per-iteration local update rule
(proximal SGD), the client-carried state (none), the server fold (a
weighted average / the staleness mix), and the wire codec (int8 deltas).
``FedAlgorithm`` owns those four pieces:

``client_init`` / ``client_step``
    Per-client state entering a local run (SCAFFOLD's control variate,
    a submodel mask) and the scan body itself. The engine supplies a
    ``StepCtx`` (value_and_grad, optimizer, anchor, trainable mask,
    server context, FedConfig) and threads ``(params, opt_state, state)``
    through the scan; the algorithm decides what a step does.
    ``client_finalize`` closes a local run: ``(w_new, new_state, msg)``
    where ``msg`` is the algorithm's server-bound side channel (SCAFFOLD's
    variate delta; empty for stateless algorithms).

``server_reduce``
    Decomposed for the batched engines as ``reduce_prepare`` (a
    per-client transform over the stacked client axis — FedHM's low-rank
    reconstruction lives here, so it runs *inside* the round program,
    under vmap and shard_map alike), the engine's weighted fold, and
    ``reduce_finish`` (fold the weighted ``msg`` sum into the server
    context — SCAFFOLD's variate update). The async path uses ``mix``:
    one staleness-weighted receive, generalizing ``fedasync._mix``.

``encode`` / ``decode``
    The wire codec, generalizing ``compression.quantize_delta`` to
    algorithm-shaped payloads: low-rank factors for ``LowRankSubmodel``,
    quantized variate deltas for ``Scaffold``.

Default ``FedProx()`` is *bit-identical* to the pre-refactor engines —
its state, context and msg are empty pytrees (zero leaves: the traced
programs are unchanged) and its hooks are the exact arithmetic the
engines inlined before. It is pinned as the parity oracle.

Compile-cache discipline: algorithm identity enters the engine memo key
through ``cache_key()`` (hashable, shared by all instances with the same
traced behavior), so the padded-scan compile cache stays one entry per
``(round shape, algorithm)``. Anything *traced* — LowRankSubmodel's
per-client rank — rides in the client state as a traced value, never in
the key: a fleet of mixed capacities still compiles ONE round program.

Mutable cross-round persistence (per-client states, the server context)
lives on the *algorithm instance* the caller owns, host-side, keyed by
real client ids — engines stay pure and memoizable.
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import compression
from repro.models import registry
from repro.optim import (apply_mask, control_variate_grad, proximal_grad,
                         sgd, trainable_mask)
from repro.types import FedConfig, ModelConfig

_tree_map = jax.tree_util.tree_map

# 2-D leaves at least this wide on both sides carry low-rank factor
# payloads; anything smaller (biases, norms, tiny heads) ships dense.
# Static so every LowRankSubmodel instance traces the same program.
_MIN_FACTOR_SIDE = 4


class StepCtx(NamedTuple):
    """What the engine hands the algorithm for one local iteration."""
    value_and_grad: Callable      # (params, batch) -> (loss, grads)
    opt: Any                      # repro.optim.Optimizer
    anchor: Any                   # the round's global model w_t
    mask: Any                     # trainable mask (0/1 pytree)
    server_ctx: Any               # algorithm's server context (broadcast)
    fed: FedConfig


class WireUpdate(NamedTuple):
    """One client update as it crosses the wire."""
    algo: str
    payload: Any                  # algorithm-shaped pytree(s)
    meta: Any                     # host-side static metadata (ranks, ...)
    base_bytes: int               # dense float payload it replaces
    wire_bytes: int


def _f32(x):
    return x.astype(jnp.float32)


def _zeros_f32_like(params):
    return _tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _tree_bytes(tree) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(tree))


def weighted_state_sum(trees_stacked, weights):
    """Σ_c w_c · tree_c over the leading client axis, in f32 (server-state
    accumulations stay f32; params casting is the engine's job)."""
    return _tree_map(
        lambda l: jnp.einsum("c,c...->...", weights, _f32(l)),
        trees_stacked)


class FedAlgorithm:
    """Base class; also the stateless-algorithm contract.

    ``stateful = False`` means state/ctx/msg are all empty pytrees and the
    engines keep their legacy entry-point outputs ``(w_new, losses)`` —
    the compiled programs gain zero leaves and stay bit-identical.
    """

    name = "base"
    stateful = False
    # route every update through encode/decode even without compress_bits
    # (LowRankSubmodel: projection happens on the wire in the async path)
    wire_always = False

    def __init__(self):
        self._states: dict = {}       # client id -> state pytree
        self._ctx: Any = None         # server context pytree
        self._fleet = None

    # -- identity ---------------------------------------------------------
    def cache_key(self):
        """Hashable identity for engine memoization / compile keying.
        Equal keys MUST mean equal traced behavior of every hook."""
        return (type(self).__name__,)

    def __repr__(self):
        return f"{type(self).__name__}()"

    # -- traced client hooks ---------------------------------------------
    def server_init(self, params_global):
        """Server-side algorithm context (broadcast to clients)."""
        return ()

    def client_init(self, params_global, client_id: int = 0):
        """Per-client carried state entering a local run."""
        return ()

    def client_step(self, ctx: StepCtx, carry, batch):
        """One local iteration — the scan body. Carry is
        ``(params, opt_state, state)``; returns ``(carry, loss)``."""
        params, opt_state, state = carry
        loss, grads = ctx.value_and_grad(params, batch)
        grads = self.local_grads(grads, params, ctx.anchor, state,
                                 ctx.server_ctx, ctx.fed)
        grads = apply_mask(grads, ctx.mask)
        params, opt_state = ctx.opt.update(grads, opt_state, params)
        return (params, opt_state, state), loss

    def local_grads(self, grads, params, anchor, state, server_ctx,
                    fed: FedConfig):
        """Gradient transform inside ``client_step`` — override this when
        the step is 'SGD on transformed gradients' (most algorithms)."""
        return proximal_grad(grads, params, anchor, fed.prox_theta)

    def client_finalize(self, w_new, anchor, state, n_iters, server_ctx,
                        fed: FedConfig):
        """Close a local run: ``(w_new, new_state, msg)``. ``n_iters`` is
        the client's true iteration count (traced in the padded round)."""
        return w_new, state, ()

    # -- traced server hooks ----------------------------------------------
    def reduce_prepare(self, w_news, anchor, states, server_ctx):
        """Per-client transform over the stacked client axis, applied
        before the weighted fold (runs inside the round program)."""
        return w_news

    def reduce_finish(self, avg_params, msg_sum, server_ctx, params_global):
        """Fold the weighted average + weighted msg sum into
        ``(new_global, new_server_ctx)``."""
        return avg_params, server_ctx

    def mix(self, params, server_ctx, w_new, msg, beta_t):
        """One async receive: Algorithm 1's staleness-weighted mix,
        ``(new_params, new_server_ctx)``. Default matches
        ``fedasync._mix`` exactly (f32 accumulate, cast back)."""
        new = _tree_map(
            lambda a, b: ((1.0 - beta_t) * _f32(a)
                          + beta_t * _f32(b)).astype(a.dtype),
            params, w_new)
        return new, server_ctx

    # -- wire codec (host-side) -------------------------------------------
    def encode(self, w_new, msg, anchor, fed: FedConfig) -> WireUpdate:
        """Client -> server payload. Default: the int8/int4 delta codec
        when ``fed.compress_bits`` is set, dense floats otherwise."""
        base = _tree_bytes(w_new)
        if fed.compress_bits:
            upd = compression.quantize_delta(w_new, anchor,
                                             fed.compress_bits)
            return WireUpdate(self.name, upd, None, base, upd.wire_bytes)
        return WireUpdate(self.name, w_new, None, base, base)

    def decode(self, wire: WireUpdate, anchor, fed: FedConfig):
        """Server-side reconstruction: ``(w_new, msg)``."""
        if isinstance(wire.payload, compression.QuantizedUpdate):
            return compression.dequantize_delta(wire.payload, anchor), ()
        return wire.payload, ()

    # -- host-side persistence (the caller's instance owns this) ----------
    def bind_fleet(self, fleet):
        """Observe the fleet driving this run (LowRankSubmodel derives
        per-client capacity from profile speed rank). No-op by default."""
        self._fleet = fleet

    def state_for(self, k: int, params):
        if not self.stateful:
            return ()
        k = int(k)
        if k not in self._states:
            self._states[k] = self.client_init(params, k)
        return self._states[k]

    def stacked_states(self, params, ids):
        """Per-client states stacked to a leading client axis for the
        batched engines (init-on-miss, keyed by real client id)."""
        if not self.stateful:
            return ()
        sts = [self.state_for(k, params) for k in ids]
        return _tree_map(lambda *ls: jnp.stack(ls), *sts)

    def store_state(self, k: int, state):
        if self.stateful:
            self._states[int(k)] = state

    def store_states(self, ids, stacked_states):
        """Commit a round's stacked new states back per client id."""
        if not self.stateful:
            return
        for j, k in enumerate(ids):
            self._states[int(k)] = _tree_map(lambda a: a[j], stacked_states)

    def ctx_for(self, params):
        if not self.stateful:
            return ()
        if self._ctx is None:
            self._ctx = self.server_init(params)
        return self._ctx

    def set_ctx(self, ctx):
        if self.stateful:
            self._ctx = ctx

    def reset(self):
        """Drop all persisted client/server algorithm state."""
        self._states.clear()
        self._ctx = None


class FedProx(FedAlgorithm):
    """The paper's proximal local SGD (§III-D) — the existing behavior,
    now as the default plug-in and the refactor's parity oracle. Stateless:
    every hook is the exact arithmetic the engines inlined before."""

    name = "fedprox"


class Scaffold(FedAlgorithm):
    """SCAFFOLD (Karimireddy et al. 2020), Option II variate update.

    Client k carries a control variate c_k (f32, shaped like params); the
    server carries c. Each local step corrects the proximal gradient by
    ``+ c - c_k`` (``optim.control_variate_grad``); after H^k steps

        c_k⁺ = c_k − c + (w_t − w_new) / (H^k · lr)
        msg  = Δc = c_k⁺ − c_k

    Sync server: c += Σ_k weight_k · Δc_k (the round's weighted fold —
    full-participation SCAFFOLD; under client sampling this applies the
    sampled estimate undamped). Async server: c += β_t · Δc — the same
    staleness damping Algorithm 1 applies to the params, so a stale
    variate cannot yank c harder than its model update yanks w.

    Clients that ran zero iterations keep their variate unchanged.
    Requires a float ``fed.lr`` (no schedules: the variate update needs
    the step size in closed form).
    """

    name = "scaffold"
    stateful = True

    def server_init(self, params_global):
        return _zeros_f32_like(params_global)

    def client_init(self, params_global, client_id: int = 0):
        return _zeros_f32_like(params_global)

    def local_grads(self, grads, params, anchor, state, server_ctx,
                    fed: FedConfig):
        grads = proximal_grad(grads, params, anchor, fed.prox_theta)
        return control_variate_grad(grads, server_ctx, state)

    def client_finalize(self, w_new, anchor, state, n_iters, server_ctx,
                        fed: FedConfig):
        lr = float(fed.lr)        # raises for schedule callables, by design
        n = jnp.maximum(jnp.asarray(n_iters, jnp.float32), 1.0)
        active = jnp.asarray(n_iters, jnp.int32) > 0
        c_new = _tree_map(
            lambda ck, c, a, w: jnp.where(
                active, ck - c + (_f32(a) - _f32(w)) / (n * lr), ck),
            state, server_ctx, anchor, w_new)
        delta_c = _tree_map(lambda cn, ck: cn - ck, c_new, state)
        return w_new, c_new, delta_c

    def reduce_finish(self, avg_params, msg_sum, server_ctx, params_global):
        new_ctx = _tree_map(lambda c, d: c + d, server_ctx, msg_sum)
        return avg_params, new_ctx

    def mix(self, params, server_ctx, w_new, msg, beta_t):
        new = _tree_map(
            lambda a, b: ((1.0 - beta_t) * _f32(a)
                          + beta_t * _f32(b)).astype(a.dtype),
            params, w_new)
        new_ctx = _tree_map(lambda c, d: c + beta_t * d, server_ctx, msg)
        return new, new_ctx

    def encode(self, w_new, msg, anchor, fed: FedConfig) -> WireUpdate:
        base = _tree_bytes(w_new) + _tree_bytes(msg)
        if not fed.compress_bits:
            return WireUpdate(self.name, (w_new, msg), None, base, base)
        upd = compression.quantize_delta(w_new, anchor, fed.compress_bits)
        zero = _zeros_f32_like(msg)
        mupd = compression.quantize_delta(msg, zero, fed.compress_bits)
        return WireUpdate(self.name, (upd, mupd), None, base,
                          upd.wire_bytes + mupd.wire_bytes)

    def decode(self, wire: WireUpdate, anchor, fed: FedConfig):
        w, m = wire.payload
        if isinstance(w, compression.QuantizedUpdate):
            msg = compression.dequantize_delta(
                m, _tree_map(lambda s: jnp.zeros_like(s, jnp.float32),
                             m.q))
            return compression.dequantize_delta(w, anchor), msg
        return w, m


def _is_factor_leaf(a) -> bool:
    shape = np.shape(a)
    return len(shape) == 2 and min(shape) >= _MIN_FACTOR_SIDE


def _static_rank(cap: float, r_full: int) -> int:
    # f32 on purpose: must agree with the traced jnp.ceil in
    # reduce_prepare for any capacity a client state can carry
    return int(max(1, min(r_full,
                          math.ceil(float(np.float32(cap)) * r_full))))


class LowRankSubmodel(FedAlgorithm):
    """Capacity-heterogeneous clients: FedHM-style low-rank updates for
    matrix leaves + subMFL-style seeded masks for the rest.

    Client k gets a capacity fraction cap_k ∈ (0, 1] — ``capacity`` scaled
    by the fleet profile's relative speed (``Fleet.capacity``: fastest
    device 1.0, slowest 0.5) once ``bind_fleet`` has run. Its state is

        {"cap": f32 scalar (traced!), "mask": 0/1 pytree}

    Training: non-factor leaves' gradients multiply a seeded 0/1 mask
    with keep-probability cap_k (the dropout-derived submodel); factor
    leaves train dense but their *delta* is rank-truncated at the server.

    Server reduce (``reduce_prepare``, inside the round program): each
    factor leaf's delta SVDs at full rank and a traced mask
    ``arange(r) < ceil(cap_k · r)`` zeroes the trailing singular values —
    per-client ranks are DATA, not shapes, so a fleet of mixed capacities
    still compiles one round program (the compile-cache invariant the
    guard-rail tests pin).

    Wire: factor leaves ship the truncated SVD factors (U_r, s_r, V_r^T)
    — quantized through the int8/int4 codec when ``fed.compress_bits`` is
    set — and everything else ships dense; ``(m+n+1)·r_k`` values per
    matrix instead of ``m·n``. The async path always routes through the
    codec (``wire_always``) so loop and scan engines see identical
    projected updates.
    """

    name = "lowrank"
    stateful = True
    wire_always = True

    def __init__(self, capacity: float = 0.25, min_capacity: float = 0.05,
                 seed: int = 0):
        super().__init__()
        if not 0.0 < capacity <= 1.0:
            raise ValueError(f"capacity must be in (0, 1], got {capacity}")
        self.capacity = float(capacity)
        self.min_capacity = float(min_capacity)
        self.seed = int(seed)
        self._caps: dict = {}

    def cache_key(self):
        # capacity/seed ride in the (traced) client state, never the key:
        # every instance shares one compiled round program per shape
        return (type(self).__name__,)

    def __repr__(self):
        return (f"LowRankSubmodel(capacity={self.capacity}, "
                f"seed={self.seed})")

    # -- per-client capacity ----------------------------------------------
    def capacity_for(self, k: int) -> float:
        k = int(k)
        if k not in self._caps:
            rel = 1.0
            if self._fleet is not None:
                rel = float(self._fleet.capacity(k))
            self._caps[k] = max(self.min_capacity,
                                min(1.0, self.capacity * rel))
        return self._caps[k]

    def set_capacity(self, k: int, cap: float):
        self._caps[int(k)] = max(self.min_capacity, min(1.0, float(cap)))

    def client_init(self, params_global, client_id: int = 0):
        cap = self.capacity_for(client_id)
        rng = np.random.default_rng((self.seed, 0x5EED, int(client_id)))

        def mask_leaf(p):
            if _is_factor_leaf(p):
                return jnp.float32(1.0)      # rank-truncated, not masked
            keep = (rng.random(np.shape(p)) < cap) | (np.size(p) <= 1)
            return jnp.asarray(keep, jnp.float32)

        return {"cap": jnp.float32(cap),
                "mask": _tree_map(mask_leaf, params_global)}

    def local_grads(self, grads, params, anchor, state, server_ctx,
                    fed: FedConfig):
        grads = proximal_grad(grads, params, anchor, fed.prox_theta)
        return _tree_map(lambda g, m: (g * m).astype(g.dtype),
                         grads, state["mask"])

    def client_finalize(self, w_new, anchor, state, n_iters, server_ctx,
                        fed: FedConfig):
        # the capacity IS the server-bound message: the wire codec and the
        # server reconstruction both need cap_k to agree on ranks
        return w_new, state, state["cap"]

    # -- server reduce ----------------------------------------------------
    def reduce_prepare(self, w_news, anchor, states, server_ctx):
        caps = states["cap"]                 # (n_clients,) traced

        def one_client(w, cap):
            def leaf(wl, al):
                if not _is_factor_leaf(al):
                    return wl
                d = _f32(wl) - _f32(al)
                u, s, vt = jnp.linalg.svd(d, full_matrices=False)
                r_full = s.shape[0]
                r_k = jnp.clip(jnp.ceil(cap * r_full), 1, r_full)
                keep = (jnp.arange(r_full) < r_k).astype(jnp.float32)
                rec = (u * (s * keep)) @ vt
                return (_f32(al) + rec).astype(wl.dtype)
            return _tree_map(leaf, w, anchor)

        return jax.vmap(one_client, in_axes=(0, 0))(w_news, caps)

    # -- wire codec -------------------------------------------------------
    def encode(self, w_new, msg, anchor, fed: FedConfig) -> WireUpdate:
        """Factor leaves ship truncated SVD factors at the client's rank
        (cap_k from ``msg``, the finalize side channel); everything else
        ships dense — both through the int8/int4 codec when
        ``fed.compress_bits`` is set."""
        cap_leaves = jax.tree_util.tree_leaves(msg)
        cap = (float(np.asarray(cap_leaves[0])) if cap_leaves
               else self.capacity)
        base = _tree_bytes(w_new)
        w_flat = jax.tree_util.tree_leaves(w_new)
        a_flat = jax.tree_util.tree_leaves(anchor)
        payload, ranks = [], []
        wire = 0
        bits = fed.compress_bits
        for wl, al in zip(w_flat, a_flat):
            if _is_factor_leaf(al):
                d = np.asarray(_f32(wl) - _f32(al))
                r = _static_rank(cap, min(d.shape))
                u, s, vt = np.linalg.svd(d, full_matrices=False)
                fac = (jnp.asarray(u[:, :r]), jnp.asarray(s[:r]),
                       jnp.asarray(vt[:r, :]))
                if bits:
                    zeros = _tree_map(jnp.zeros_like, fac)
                    qf = compression.quantize_delta(fac, zeros, bits)
                    payload.append(qf)
                    wire += qf.wire_bytes
                else:
                    payload.append(fac)
                    wire += _tree_bytes(fac)
                ranks.append(r)
            else:
                if bits:
                    q = compression.quantize_delta(wl, al, bits)
                    payload.append(q)
                    wire += q.wire_bytes
                else:
                    payload.append(wl)
                    wire += wl.size * wl.dtype.itemsize
                ranks.append(0)
        return WireUpdate(self.name, payload,
                          {"ranks": tuple(ranks), "cap": cap}, base, wire)

    def decode(self, wire: WireUpdate, anchor, fed: FedConfig):
        a_flat, treedef = jax.tree_util.tree_flatten(anchor)
        out = []
        for pl, al, r in zip(wire.payload, a_flat, wire.meta["ranks"]):
            if r:
                if isinstance(pl, compression.QuantizedUpdate):
                    zeros = _tree_map(
                        lambda q: jnp.zeros(q.shape, jnp.float32), pl.q)
                    u, s, vt = compression.dequantize_delta(pl, zeros)
                else:
                    u, s, vt = pl
                rec = (_f32(u) * _f32(s)) @ _f32(vt)
                out.append((_f32(al) + rec).astype(al.dtype))
            elif isinstance(pl, compression.QuantizedUpdate):
                out.append(compression.dequantize_delta(pl, al))
            else:
                out.append(pl)
        return (jax.tree_util.tree_unflatten(treedef, out),
                jnp.float32(wire.meta["cap"]))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ALGORITHMS = {
    "fedprox": FedProx,
    "scaffold": Scaffold,
    "lowrank": LowRankSubmodel,
}


def make_algorithm(name, **kwargs) -> FedAlgorithm:
    """Validated algorithm constructor (the ``EngineSpec.from_str`` of the
    algorithm knob). Accepts an instance (passed through), or a name from
    ``ALGORITHMS``; unknown names raise naming the valid options."""
    if isinstance(name, FedAlgorithm):
        return name
    try:
        cls = ALGORITHMS[str(name).lower()]
    except KeyError:
        raise ValueError(
            f"algorithm must be one of {sorted(ALGORITHMS)}, "
            f"got {name!r}") from None
    return cls(**kwargs)


# ---------------------------------------------------------------------------
# Loop oracles: per-iteration dispatch, algorithm-aware
# ---------------------------------------------------------------------------

# jitted per-iteration steps memoized per (cfg, fed, algorithm identity) —
# the algorithm hooks are pure per cache_key, so any instance with the
# same key reuses the compiled step
_STEP_CACHE: dict = {}
_STEP_CACHE_MAX = 16


def make_alg_step(cfg: ModelConfig, fed: FedConfig,
                  algorithm: FedAlgorithm):
    """One algorithm-aware local iteration, jitted — the per-iteration
    oracle generalizing ``fedasync.make_client_step``.

    (params, opt_state, state, anchor, batch, mask, server_ctx)
        -> (params, opt_state, state, loss)
    """
    key = (cfg, fed, algorithm.cache_key())
    if key in _STEP_CACHE:
        return _STEP_CACHE[key]
    opt = sgd(fed.lr, fed.momentum, fed.weight_decay)

    def task_loss(params, batch):
        return registry.loss_fn(params, cfg, batch)[0]

    # Oracle step, memoized here (bounded) rather than via JitCache: its
    # identity is part of the loop-vs-engine parity contract.
    # repro-lint: disable=R1
    @jax.jit
    def step(params, opt_state, state, anchor, batch, mask, server_ctx):
        ctx = StepCtx(jax.value_and_grad(task_loss), opt, anchor, mask,
                      server_ctx, fed)
        (params, opt_state, state), loss = algorithm.client_step(
            ctx, (params, opt_state, state), batch)
        return params, opt_state, state, loss

    while len(_STEP_CACHE) >= _STEP_CACHE_MAX:
        _STEP_CACHE.pop(next(iter(_STEP_CACHE)))
    _STEP_CACHE[key] = (step, opt)
    return step, opt


def client_update_loop(params_global, batches, cfg: ModelConfig,
                       fed: FedConfig, algorithm: FedAlgorithm,
                       client_id: int = 0, num_iters=None, mask=None,
                       server_ctx=None, state=None):
    """Algorithm-aware legacy loop: one jitted step + one host sync per
    iteration — the parity oracle for the scan/padded engines.

    Returns ``(w_new, new_state, msg, losses)`` (losses as floats).
    Persists the client's new state on ``algorithm``.
    """
    step, opt = make_alg_step(cfg, fed, algorithm)
    if mask is None:
        mask = trainable_mask(params_global, fed.trainable)
    if server_ctx is None:
        server_ctx = algorithm.ctx_for(params_global)
    if state is None:
        state = algorithm.state_for(client_id, params_global)
    params, anchor = params_global, params_global
    opt_state = opt.init(params)
    H = num_iters if num_iters is not None else fed.local_iters_max
    losses = []
    for _, batch in zip(range(H), batches):
        params, opt_state, state, loss = step(
            params, opt_state, state, anchor, batch, mask, server_ctx)
        losses.append(float(loss))
    w_new, new_state, msg = algorithm.client_finalize(
        params, anchor, state, jnp.int32(len(losses)), server_ctx, fed)
    algorithm.store_state(client_id, new_state)
    return w_new, new_state, msg, losses


def server_reduce(algorithm: FedAlgorithm, params_global, w_news, states,
                  msgs, weights, server_ctx=None, commit: bool = True):
    """Eager algorithm-aware round fold — the loop oracle's server half
    (the engines run the same prepare/fold/finish inside their programs).

    ``w_news``/``states``/``msgs`` are per-client lists; returns the new
    global params and (with ``commit``) persists the new server context.
    """
    weights = jnp.asarray(weights, jnp.float32)
    if server_ctx is None:
        server_ctx = algorithm.ctx_for(params_global)
    w_stack = _tree_map(lambda *ls: jnp.stack(ls), *w_news)
    if algorithm.stateful:
        st_stack = _tree_map(lambda *ls: jnp.stack(ls), *states)
        w_stack = algorithm.reduce_prepare(w_stack, params_global,
                                           st_stack, server_ctx)
    avg = _tree_map(
        lambda l, p: jnp.einsum("c,c...->...", weights,
                                _f32(l)).astype(p.dtype),
        w_stack, params_global)
    msg_sum = ()
    if msgs and jax.tree_util.tree_leaves(msgs[0]):
        m_stack = _tree_map(lambda *ls: jnp.stack(ls), *msgs)
        msg_sum = weighted_state_sum(m_stack, weights)
    new_global, new_ctx = algorithm.reduce_finish(avg, msg_sum, server_ctx,
                                                  params_global)
    if commit:
        algorithm.set_ctx(new_ctx)
    return new_global, new_ctx
