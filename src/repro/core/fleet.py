"""Streaming-fleet layer: million-client populations without million-client
resident state, plus the unified engine selector.

The simulator used to take two parallel positional sequences —
``fleet: Sequence[DeviceProfile]`` and ``client_data: Sequence[Callable]``
— which forces every client's loader (and profile, and H^k) to exist up
front: fine at FedMultimodal scale (~10^3 clients), impossible at the
10^6-client populations the ROADMAP names. This module replaces that pair
with one object:

``FleetSpec``
    A *description* of a population: its size, a seeded device-profile
    distribution, and a data rule (a shared dataset plus a partition
    strategy from ``data/partition``, or an arbitrary ``data_fn``). A
    sampled client's ``DeviceProfile``, loader, and local-iteration budget
    H^k are all pure seeded functions of the client id — nothing is held
    resident until a client is actually sampled.

``Fleet``
    The runtime surface ``run_sync``/``run_async`` consume. Built either
    ``from_spec`` (streaming: client state materializes on demand into a
    small cache and is ``release``d when the client leaves the
    sampled/in-flight set — ``max_resident`` is the asserted memory
    model) or ``from_lists`` (explicit small fleets; the deprecation shim
    for the old two-sequence signature routes here). One validated
    constructor replaces the ad-hoc length checks both entry points used
    to duplicate.

``EngineSpec``
    The one definition of the ``engine=`` knob that used to be stringly
    typed ("scan" | "loop" | "shard", now + "hier") across ``simulator``,
    ``fedavg`` and ``launch/train.py``. ``from_str`` validates against the
    accepted set (error messages name the valid options); ``build_sync``
    maps a member to its round engine.

See docs/fleet.md for sampling semantics, the hierarchy layout, and the
memory model.
"""
from __future__ import annotations

import enum
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Optional, Sequence, Tuple


import numpy as np


# ---------------------------------------------------------------------------
# Device profiles (paper Tables IV/V) — moved here from core/simulator so the
# fleet layer has no import cycle; simulator re-exports for compatibility.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DeviceProfile:
    name: str
    # seconds per local epoch, per dataset (paper Table IV)
    epoch_seconds: float
    # seconds to evaluate the full test set (paper Table V)
    test_seconds: float = 0.0
    # upload latency for one model (seconds); the paper folds this into the
    # epoch time — kept separate so network heterogeneity can be studied
    upload_seconds: float = 0.0


# Paper Table IV / V — HMDB51 column.
JETSON_FLEET_HMDB51 = (
    DeviceProfile("jetson-nano", 391.1, 181.4),
    DeviceProfile("jetson-tx2", 293.1, 116.3),
    DeviceProfile("jetson-xavier-nx", 121.3, 89.4),
    DeviceProfile("jetson-agx-xavier", 84.5, 68.3),
)

# Paper Table IV / V — UCF101 column.
JETSON_FLEET_UCF101 = (
    DeviceProfile("jetson-nano", 2691.6, 621.3),
    DeviceProfile("jetson-tx2", 2001.4, 381.2),
    DeviceProfile("jetson-xavier-nx", 821.9, 322.5),
    DeviceProfile("jetson-agx-xavier", 572.1, 217.7),
)


# ---------------------------------------------------------------------------
# EngineSpec — the single definition of the engine knob
# ---------------------------------------------------------------------------

class EngineSpec(enum.Enum):
    """Client-execution engine selector.

    SCAN   compiled ``lax.scan``/vmap engine (padded masked scan for
           heterogeneous H^k) — the default everywhere.
    LOOP   legacy per-iteration dispatch loop; the parity oracle.
    SHARD  SCAN + the sync round's client axis split over a 1-D
           ``('clients',)`` device mesh with a flat psum (sync only).
    HIER   SCAN + a two-level ``('edge', 'clients')`` mesh: clients →
           edge aggregators → server as a *nested* psum, provably equal
           to the flat weighted average (sync only).
    """

    SCAN = "scan"
    LOOP = "loop"
    SHARD = "shard"
    HIER = "hier"

    @classmethod
    def from_str(cls, value, allowed: Optional[Tuple["EngineSpec", ...]]
                 = None) -> "EngineSpec":
        """Validate ``value`` (a string or an EngineSpec) into a member.

        ``allowed`` restricts the accepted subset (e.g. the async path has
        no fleet-wide round to shard); the error names the valid options.
        """
        if isinstance(value, cls):
            spec = value
        else:
            try:
                spec = cls(value)
            except ValueError:
                raise ValueError(
                    f"engine must be one of "
                    f"{[m.value for m in cls]}, got {value!r}") from None
        if allowed is not None and spec not in allowed:
            raise ValueError(
                f"engine {spec.value!r} not supported here; valid options: "
                f"{[m.value for m in allowed]}")
        return spec

    def build_sync(self, cfg, fed, mesh=None, algorithm=None):
        """The sync-round engine for this member (None for LOOP — the
        caller owns the per-iteration oracle path). ``algorithm`` is a
        ``core.algorithms.FedAlgorithm`` (None = the default FedProx)."""
        from repro.core import fed_engine
        if self is EngineSpec.SCAN:
            return fed_engine.make_sync_round(cfg, fed,
                                              algorithm=algorithm)
        if self is EngineSpec.SHARD:
            return fed_engine.make_sharded_sync_round(cfg, fed, mesh=mesh,
                                                      algorithm=algorithm)
        if self is EngineSpec.HIER:
            return fed_engine.make_hierarchical_sync_round(
                cfg, fed, mesh=mesh, algorithm=algorithm)
        return None


# engine subsets accepted by the two simulator entry points
SYNC_ENGINES = (EngineSpec.SCAN, EngineSpec.LOOP, EngineSpec.SHARD,
                EngineSpec.HIER)
ASYNC_ENGINES = (EngineSpec.SCAN, EngineSpec.LOOP)


# ---------------------------------------------------------------------------
# FleetSpec — a population described, not materialized
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FleetSpec:
    """Seeded description of a client population.

    ``profiles`` + ``profile_weights`` is the device-profile distribution:
    client k's profile is an iid seeded draw (``profile_index``), so two
    FleetSpecs with the same seed agree client-by-client — sampling and
    materialization see the same fleet.

    Data: either ``data_fn(k) -> Callable[[], Iterable]`` (full control),
    or ``dataset`` + ``partition``:

    - ``"shared"``: every client draws its own seeded batch stream from
      the whole dataset (the only partition that makes sense when the
      population dwarfs the item count);
    - ``"iid"``: client k gets ``data.partition.iid_shard(...)`` — the
      on-demand, bit-identical equivalent of ``iid_partition`` that never
      allocates the other 10^6 - 1 index lists.

    The local-iteration budget H^k is resource-aware like the legacy
    fleet's: the profile's speed rank among ``profiles`` maps linearly
    from H_max (fastest) to H_min (slowest).
    """

    population: int
    profiles: Tuple[DeviceProfile, ...]
    profile_weights: Optional[Tuple[float, ...]] = None
    seed: int = 0
    # data rule (one of dataset+partition or data_fn)
    dataset: Any = None
    batch_size: int = 4
    steps: int = 4
    partition: str = "shared"      # "shared" | "iid"
    data_fn: Optional[Callable[[int], Callable[[], Iterable]]] = None

    def __post_init__(self):
        if self.population < 1:
            raise ValueError(f"population must be >= 1, got "
                             f"{self.population}")
        if not self.profiles:
            raise ValueError("FleetSpec needs at least one DeviceProfile")
        if self.profile_weights is not None \
                and len(self.profile_weights) != len(self.profiles):
            raise ValueError(
                f"profile_weights ({len(self.profile_weights)}) must match "
                f"profiles ({len(self.profiles)})")
        if self.partition not in ("shared", "iid"):
            raise ValueError(f"partition must be 'shared' or 'iid', got "
                             f"{self.partition!r}")
        if self.data_fn is None and self.dataset is None:
            raise ValueError("FleetSpec needs a dataset or a data_fn")

    # -- per-client draws (pure functions of (spec, k)) ------------------
    def profile_index(self, k: int) -> int:
        rng = np.random.default_rng((self.seed, 0x9E37, int(k)))
        p = None
        if self.profile_weights is not None:
            w = np.asarray(self.profile_weights, np.float64)
            p = w / w.sum()
        return int(rng.choice(len(self.profiles), p=p))

    def profile(self, k: int) -> DeviceProfile:
        return self.profiles[self.profile_index(k)]

    def iters(self, k: int, fed) -> int:
        """H^k from the profile's speed rank among the spec's templates
        (O(#profiles), not O(population) — no fleet-wide argsort)."""
        speeds = sorted(p.epoch_seconds for p in self.profiles)
        rank = speeds.index(self.profiles[self.profile_index(k)]
                            .epoch_seconds)
        frac = rank / max(len(self.profiles) - 1, 1)
        return int(round(fed.local_iters_max
                         - frac * (fed.local_iters_max
                                   - fed.local_iters_min)))

    def capacity(self, k: int, lo: float = 0.5, hi: float = 1.0) -> float:
        """Relative compute capacity of client k's device: the profile's
        speed rank among the spec's templates mapped linearly from ``hi``
        (fastest) to ``lo`` (slowest) — the same rank rule as ``iters``,
        consumed by capacity-adaptive algorithms
        (``algorithms.LowRankSubmodel``)."""
        speeds = sorted(p.epoch_seconds for p in self.profiles)
        rank = speeds.index(self.profiles[self.profile_index(k)]
                            .epoch_seconds)
        frac = rank / max(len(self.profiles) - 1, 1)
        return float(hi - frac * (hi - lo))

    def data(self, k: int, perm: np.ndarray | None = None,
             visit: int = 0):
        """Client k's fresh-iterator factory (the ``client_data[k]``
        contract) for its ``visit``-th sampling — a pure function of
        (spec, k, visit), which is what makes a streamed fleet
        bit-identical to its materialized twin under any sampling
        pattern. ``perm`` optionally reuses the cached IID permutation."""
        if self.data_fn is not None:
            return self.data_fn(k)
        from repro.data import BatchLoader, partition as part
        indices = None
        if self.partition == "iid":
            indices = part.iid_shard(len(self.dataset), self.population,
                                     int(k), seed=self.seed, perm=perm)
        seed = int(k) if visit == 0 else int(
            np.random.default_rng((self.seed, 0xDA7A, int(k), int(visit)))
            .integers(np.iinfo(np.int64).max))
        return BatchLoader(self.dataset, self.batch_size, self.steps,
                           seed=seed, indices=indices)


# ---------------------------------------------------------------------------
# Fleet — the runtime surface
# ---------------------------------------------------------------------------

class Fleet:
    """Client population handed to ``run_sync``/``run_async``.

    Two modes share one interface:

    - *resident* (``from_lists``): profiles and loaders are explicit
      sequences; everything is resident for the run (legacy semantics,
      including the fleet-wide argsort H^k assignment).
    - *streaming* (``from_spec``): client state builds on demand from the
      ``FleetSpec`` into ``_cache`` and is dropped by ``release``;
      ``max_resident`` is the high-water mark of concurrently
      materialized clients, which sampled rounds keep at O(sampled) and
      steady-state async at O(in-flight) — the memory model tests and
      ``benchmarks/fleet_bench.py`` assert. Each ``data(k)`` call is a
      fresh loader for that client's next *visit* (``_visits`` keeps one
      int per ever-visited client — bounded by the dispatch count, never
      by the population), so the stream is a pure function of
      (spec, k, visit) and survives release/re-sample bit-identically.
    """

    def __init__(self, *, population: int, spec: FleetSpec | None = None,
                 profiles: Sequence[DeviceProfile] | None = None,
                 client_data: Sequence[Callable[[], Iterable]] | None = None):
        self.population = int(population)
        self.spec = spec
        self._profiles = list(profiles) if profiles is not None else None
        self._client_data = (list(client_data) if client_data is not None
                             else None)
        self._cache: dict = {}       # k -> DeviceProfile (resident state)
        self._visits: dict = {}      # k -> samplings so far (survives release)
        self._pinned = False         # materialized twin: release() no-op
        self.max_resident = 0 if spec is not None else self.population
        self._iters_cache: dict = {}
        self._iid_perm: np.ndarray | None = None

    # -- constructors ----------------------------------------------------
    @classmethod
    def from_lists(cls, profiles: Sequence[DeviceProfile],
                   client_data: Sequence[Callable[[], Iterable]]) -> "Fleet":
        """Explicit small fleet — the validated replacement for the old
        parallel (fleet, client_data) positional pair."""
        if len(profiles) != len(client_data):
            raise ValueError(
                f"fleet profiles ({len(profiles)}) and client_data "
                f"({len(client_data)}) must agree")
        if not len(profiles):
            raise ValueError("empty fleet")
        return cls(population=len(profiles), profiles=profiles,
                   client_data=client_data)

    @classmethod
    def from_spec(cls, spec: FleetSpec) -> "Fleet":
        """Streaming fleet: clients materialize on demand."""
        return cls(population=spec.population, spec=spec)

    @classmethod
    def resolve(cls, fleet, client_data, fed) -> "Fleet":
        """The one validated constructor behind both simulator entry
        points — including the deprecation shim for the old two-sequence
        signature (kept working for one release)."""
        if isinstance(fleet, Fleet):
            if client_data is not None:
                raise ValueError(
                    "client_data must be None when passing a Fleet — the "
                    "Fleet already carries each client's data")
            out = fleet
        elif isinstance(fleet, FleetSpec):
            if client_data is not None:
                raise ValueError(
                    "client_data must be None when passing a FleetSpec")
            out = cls.from_spec(fleet)
        else:
            if client_data is None:
                raise ValueError(
                    "pass a Fleet/FleetSpec, or the legacy "
                    "(fleet profiles, client_data) sequence pair")
            warnings.warn(
                "run_sync/run_async with parallel fleet/client_data "
                "sequences is deprecated; pass "
                "Fleet.from_lists(profiles, client_data) (or a FleetSpec "
                "for streaming populations) instead",
                DeprecationWarning, stacklevel=3)
            out = cls.from_lists(fleet, client_data)
        if out.population != fed.num_clients:
            raise ValueError(
                f"fleet population ({out.population}) and fed.num_clients "
                f"({fed.num_clients}) must agree")
        m = getattr(fed, "clients_per_round", 0)
        if m < 0 or m > out.population:
            raise ValueError(
                f"fed.clients_per_round ({m}) must be in "
                f"[0, population={out.population}]")
        return out

    # -- streaming <-> resident ------------------------------------------
    def materialize(self) -> "Fleet":
        """Resident twin of a streaming fleet: every client's profile
        built up front and pinned (release is a no-op), small populations
        only — this is what the bit-identity property tests compare
        against. Data still flows through the spec's (k, visit) rule, so
        any sampling pattern sees the exact streams the streaming fleet
        would."""
        if self.spec is None:
            return self
        out = Fleet(population=self.population, spec=self.spec)
        for k in range(self.population):
            out._materialize_client(k)
        out._pinned = True
        return out

    def _perm(self):
        if self.spec is not None and self.spec.partition == "iid" \
                and self.spec.data_fn is None and self._iid_perm is None:
            self._iid_perm = np.random.default_rng(
                self.spec.seed).permutation(len(self.spec.dataset))
        return self._iid_perm

    def _materialize_client(self, k: int):
        if k not in self._cache:
            self._cache[k] = self.spec.profile(k)
            self.max_resident = max(self.max_resident, len(self._cache))
        return self._cache[k]

    # -- per-client state ------------------------------------------------
    def profile(self, k: int) -> DeviceProfile:
        if self._profiles is not None:
            return self._profiles[k]
        return self._materialize_client(k)

    def data(self, k: int) -> Callable[[], Iterable]:
        """Client k's fresh-iterator factory for its next visit. Spec
        fleets hand out a new deterministic (spec, k, visit)-seeded
        loader per call — so streamed and materialized fleets agree
        bit-for-bit whatever the release pattern; list fleets return the
        caller's own (stateful) loader, the legacy contract."""
        if self._client_data is not None:
            return self._client_data[k]
        self._materialize_client(k)
        visit = self._visits.get(k, 0)
        self._visits[k] = visit + 1
        return self.spec.data(k, perm=self._perm(), visit=visit)

    def iters(self, k: int, fed) -> int:
        """Resource-aware H^k ∈ [H_min, H_max].

        Resident list fleets keep the legacy rule (fleet-wide argsort of
        epoch_seconds, ties broken by position); spec fleets rank the
        client's *profile* among the spec's templates so no O(population)
        pass is ever needed.
        """
        if self.spec is not None:
            return self.spec.iters(k, fed)
        key = (fed.local_iters_min, fed.local_iters_max)
        if key not in self._iters_cache:
            order = np.argsort([p.epoch_seconds for p in self._profiles])
            H = np.empty(self.population, np.int64)
            for rank, j in enumerate(order):
                frac = rank / max(self.population - 1, 1)
                H[int(j)] = int(round(fed.local_iters_max
                                      - frac * (fed.local_iters_max
                                                - fed.local_iters_min)))
            self._iters_cache[key] = H
        return int(self._iters_cache[key][k])

    def capacity(self, k: int, lo: float = 0.5, hi: float = 1.0) -> float:
        """Relative compute capacity of client k ∈ [lo, hi] by device
        speed rank — the ``iters`` rule's continuous twin (fastest device
        ``hi``, slowest ``lo``). Spec fleets rank the client's profile
        among the spec templates (O(#profiles)); list fleets use the
        cached fleet-wide argsort. Capacity-adaptive algorithms
        (``algorithms.LowRankSubmodel.bind_fleet``) scale their per-client
        compression budget by this."""
        if self.spec is not None:
            return self.spec.capacity(k, lo, hi)
        key = ("capacity", lo, hi)
        if key not in self._iters_cache:
            order = np.argsort([p.epoch_seconds for p in self._profiles])
            caps = np.empty(self.population, np.float64)
            for rank, j in enumerate(order):
                frac = rank / max(self.population - 1, 1)
                caps[int(j)] = hi - frac * (hi - lo)
            self._iters_cache[key] = caps
        return float(self._iters_cache[key][k])

    @property
    def resident(self) -> int:
        """Clients currently holding materialized state."""
        if self.spec is None:
            return self.population
        return len(self._cache)

    def release(self, ks) -> None:
        """Drop materialized state for clients leaving the sampled /
        in-flight set (no-op for resident list fleets)."""
        if self.spec is None or self._pinned:
            return
        for k in np.atleast_1d(ks):
            self._cache.pop(int(k), None)

    # -- sampling --------------------------------------------------------
    def sample(self, rng: np.random.Generator, m: int,
               exclude=()) -> np.ndarray:
        """Draw ``m`` distinct client ids uniformly from the population,
        excluding ``exclude`` (the in-flight set). O(m) expected for
        populations that dwarf m (rejection sampling); exact
        permutation-based draw for small populations."""
        exclude = set(int(e) for e in exclude)
        avail = self.population - len(exclude)
        if m > avail:
            raise ValueError(
                f"cannot sample {m} clients from a population of "
                f"{self.population} with {len(exclude)} excluded")
        if self.population <= 4 * (m + len(exclude)) + 1024:
            pool = np.array([k for k in range(self.population)
                             if k not in exclude], np.int64)
            return np.asarray(rng.choice(pool, size=m, replace=False),
                              np.int64)
        out: list = []
        seen = set(exclude)
        while len(out) < m:
            for d in rng.integers(0, self.population, size=m):
                d = int(d)
                if d not in seen:
                    seen.add(d)
                    out.append(d)
                    if len(out) == m:
                        break
        return np.asarray(out, np.int64)
