"""Asynchronous federated optimization (paper Algorithm 1).

Server: on receiving (w_new, τ) from any client at global epoch t,
    β_t = β · s(t - τ),   s(x) = (1 + x)^{-a}        (paper §V-C)
    w_t = (1 - β_t) · w_{t-1} + β_t · w_new

Client k: from the received global (w_t, t), runs H ∈ [H_min, H_max] local
SGD iterations on g_{w_t}(w; d) = l(w; d) + (θ/2)||w - w_t||².

Both halves are jitted pure functions; the asynchronous event order is
driven by core/simulator.py (or a real multi-pod launcher).

This module is the *reference* implementation: one jitted step per local
iteration, one server mix per receive. The compiled hot path lives in
``core/fed_engine.py`` — H iterations fuse into one ``lax.scan`` program,
concurrent dispatches with per-client H^k batch into one padded vmap
program (docs/fed_engine.md) — and is tested for float32 parity against
the loops here.

Nothing here scales with the population: the server state is one model
plus an epoch counter, and each mix touches one (or one group of)
received update(s). That is what lets ``core/fleet.py`` drive Algorithm 1
over 10^6-client streaming populations with only the sampled in-flight
set resident (docs/fleet.md).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.compile_cache import JitCache
from repro.models import registry
from repro.optim import apply_mask, proximal_grad, sgd, trainable_mask
from repro.types import FedConfig, ModelConfig

# Every server mix in the process shares one counted jit pool: the mixing
# programs are config-independent, and JitCache.num_compiled makes the
# "one program per group size" claim observable (and guard-rail testable).
_JITS = JitCache()


def staleness_fn(a: float) -> Callable:
    """s(x) = (1+x)^{-a}; s(0)=1, monotonically decreasing (paper §IV-A)."""
    def s(x):
        return (1.0 + jnp.maximum(x, 0).astype(jnp.float32)) ** (-a)
    return s


def mixing_weight(fed: FedConfig, t, tau):
    return fed.mixing_beta * staleness_fn(fed.staleness_a)(t - tau)


@dataclass
class ServerState:
    params: Any
    t: int = 0                 # global epoch counter
    total_updates: int = 0


def _mix_impl(params, w_new, beta_t):
    return jax.tree_util.tree_map(
        lambda a, b: ((1.0 - beta_t) * a.astype(jnp.float32)
                      + beta_t * b.astype(jnp.float32)).astype(a.dtype),
        params, w_new)


def _mix(params, w_new, beta_t):
    """One receive applied: dispatches through the shared ``JitCache``."""
    return _JITS.call("mix", _mix_impl, (), (params, w_new, beta_t))


def _mix_many_impl(params, betas, *w_news):
    """Fused sequential mix: m receives applied in order as ONE program.

    ``w_news`` are the m client models (separate pytrees — stacked to a
    leading update axis *inside* the trace, so the host pays one dispatch,
    not one ``jnp.stack`` per leaf) and ``betas`` is (m,); a ``lax.scan``
    threads the server params through the m mixing steps, each the exact
    arithmetic of ``_mix`` (f32 accumulate, cast back per step), so the
    result matches m chained ``_mix`` calls — Algorithm 1's sequential
    mixing order is preserved, only the dispatch count collapses from m
    to 1. The update count m is a static shape: one compile per group
    size, bounded by the fleet size (and the staleness bound K+1).
    """
    w_stack = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *w_news)

    def body(p, xs):
        w, b = xs
        return jax.tree_util.tree_map(
            lambda a, c: ((1.0 - b) * a.astype(jnp.float32)
                          + b * c.astype(jnp.float32)).astype(a.dtype),
            p, w), None

    out, _ = jax.lax.scan(body, params, (w_stack, betas))
    return out


def _mix_many(params, betas, *w_news):
    """Fused group mix via the shared ``JitCache`` ("mix_many" entry; one
    traced program per group size m, counted by ``num_compiled``)."""
    return _JITS.call("mix_many", _mix_many_impl,
                      (), (params, betas) + tuple(w_news))


def make_server_update(fed: FedConfig):
    """Jitted mixing update: (w_{t-1}, w_new, β_t) -> w_t.

    The mixing program is config-independent (β_t arrives as an argument),
    so every FedConfig shares ONE jitted function: ``server_receive``
    with ``mix=None`` used to build a fresh ``jax.jit`` wrapper on every
    receive, paying trace+compile for each update it applied.
    """
    return _mix


def make_batched_server_update(fed: FedConfig):
    """Jitted fused mix for a group of receives: (w, βs, *w_news) -> w.

    Config-independent like ``make_server_update`` — every FedConfig
    shares the one jitted ``lax.scan`` program per group size.
    """
    return _mix_many


def group_mixing_weights(fed: FedConfig, t: int, taus):
    """(staleness, β_t) for each of a group of receives applied in order.

    The i-th receive of the group lands at global epoch ``t + i``, so its
    staleness is ``clamp(t + i - τ_i, 0, K)`` — identical to what m
    chained ``server_receive`` calls would compute.
    """
    stals, betas = [], []
    for i, tau in enumerate(taus):
        s = min(max(t + i - int(tau), 0), fed.max_staleness)
        stals.append(s)
        betas.append(float(fed.mixing_beta
                           * (1.0 + s) ** (-fed.staleness_a)))
    return stals, betas


def server_receive(state: ServerState, w_new, tau: int, fed: FedConfig,
                   mix=None) -> ServerState:
    """One server step of Algorithm 1."""
    if mix is None:
        mix = make_server_update(fed)
    # staleness = global updates applied since the client grabbed the model;
    # s(0) = 1 when none intervened. Assumption 3 clamps at K. The formula
    # lives in group_mixing_weights so the windowed path can't diverge.
    _, (beta_t,) = group_mixing_weights(fed, state.t, [tau])
    params = mix(state.params, w_new, jnp.float32(beta_t))
    return ServerState(params=params, t=state.t + 1,
                       total_updates=state.total_updates + 1)


# per-algorithm (mix, mix_many) closures, memoized by cache_key() —
# JitCache entries need distinct callables per entry name, and each
# algorithm's mixing programs count separately in num_compiled
_ALG_MIX_FNS: dict = {}


def _alg_mix_fns(algorithm):
    """Algorithm-aware mixing dispatchers sharing the module ``_JITS``.

    ``mix`` is one receive — ``algorithm.mix`` (params + server context);
    ``mix_many`` is the fused group scan, the algorithm-generalized
    ``_mix_many`` (stacks models AND msgs inside the trace, threads
    ``(params, ctx)`` through the m sequential mixes).
    """
    key = algorithm.cache_key()
    if key in _ALG_MIX_FNS:
        return _ALG_MIX_FNS[key]

    def mix_impl(params, ctx, w_new, msg, beta_t):
        return algorithm.mix(params, ctx, w_new, msg, beta_t)

    def mix_many_impl(params, ctx, betas, *wm):
        m = len(wm) // 2
        w_stack = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *wm[:m])
        msg_stack = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls),
                                           *wm[m:])

        def body(carry, xs):
            p, c = carry
            w, msg, b = xs
            return algorithm.mix(p, c, w, msg, b), None

        carry, _ = jax.lax.scan(body, (params, ctx),
                                (w_stack, msg_stack, betas))
        return carry

    def mix(params, ctx, w_new, msg, beta_t):
        return _JITS.call(("alg_mix",) + key, mix_impl, (),
                          (params, ctx, w_new, msg, beta_t))

    def mix_many(params, ctx, betas, *wm):
        return _JITS.call(("alg_mix_many",) + key, mix_many_impl, (),
                          (params, ctx, betas) + tuple(wm))

    _ALG_MIX_FNS[key] = (mix, mix_many)
    return mix, mix_many


def server_receive_many(state: ServerState, updates, fed: FedConfig,
                        mix_many=None, mix=None, algorithm=None,
                        server_ctx=None):
    """Apply a group of receives ``[(w_new, τ), ...]`` in order, fused.

    Semantically m consecutive ``server_receive`` calls — each update's
    β_t is computed at its position in the group (``group_mixing_weights``)
    and the mixes apply sequentially — but dispatched as ONE jitted
    ``lax.scan`` program instead of m separate ``_mix`` calls. This is the
    server half of the simulator's staleness-bounded micro-batching window
    (``simulator.run_async(window=...)``).

    Singleton groups stay on the scalar mix path (``mix``, default the
    shared ``_mix``) — at window=0 that is every receive, keeping it
    bit-identical to the event-by-event loop; ``mix_many`` only runs for
    m ≥ 2.

    Returns ``(new_state, stalenesses, betas)`` so callers can trace each
    receive without recomputing Algorithm 1's weights.

    With a *stateful* ``algorithm``, updates are ``(w_new, msg, τ)``
    triples, the mixes are ``algorithm.mix`` (threading the server
    context), and the return is ``(new_state, new_ctx, stals, betas)``.
    The singleton/group split is preserved.
    """
    if algorithm is not None and algorithm.stateful:
        if server_ctx is None:
            server_ctx = algorithm.ctx_for(state.params)
        amix, amix_many = _alg_mix_fns(algorithm)
        taus = [tau for _, _, tau in updates]
        stals, betas = group_mixing_weights(fed, state.t, taus)
        if len(updates) == 1:
            w_new, msg, _ = updates[0]
            params, new_ctx = amix(state.params, server_ctx, w_new, msg,
                                   jnp.float32(betas[0]))
        else:
            params, new_ctx = amix_many(
                state.params, server_ctx, jnp.asarray(betas, jnp.float32),
                *[w for w, _, _ in updates],
                *[m for _, m, _ in updates])
        return (ServerState(params=params, t=state.t + len(updates),
                            total_updates=(state.total_updates
                                           + len(updates))),
                new_ctx, stals, betas)
    if mix_many is None:
        mix_many = make_batched_server_update(fed)
    taus = [tau for _, tau in updates]
    stals, betas = group_mixing_weights(fed, state.t, taus)
    if len(updates) == 1:        # singleton: stay on the scalar mix path
        if mix is None:
            mix = make_server_update(fed)
        w_new, _ = updates[0]
        params = mix(state.params, w_new, jnp.float32(betas[0]))
        return (ServerState(params=params, t=state.t + 1,
                            total_updates=state.total_updates + 1),
                stals, betas)
    params = mix_many(state.params, jnp.asarray(betas, jnp.float32),
                      *[w for w, _ in updates])
    return (ServerState(params=params, t=state.t + len(updates),
                        total_updates=state.total_updates + len(updates)),
            stals, betas)


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------

def make_client_step(cfg: ModelConfig, fed: FedConfig, loss_kwargs=None):
    """One proximal local SGD iteration, jitted.

    (params, opt_state, anchor, batch) -> (params, opt_state, loss)
    """
    loss_kwargs = dict(loss_kwargs or {})
    opt = sgd(fed.lr, fed.momentum, fed.weight_decay)

    def task_loss(params, batch):
        return registry.loss_fn(params, cfg, batch, **loss_kwargs)[0]

    # Reference oracle step: make_client_step is memoized per (cfg, fed)
    # upstream, so this jit is created once per config and its identity is
    # part of the parity-test contract.
    # repro-lint: disable=R1
    @jax.jit
    def step(params, opt_state, anchor, batch, mask):
        loss, grads = jax.value_and_grad(task_loss)(params, batch)
        grads = proximal_grad(grads, params, anchor, fed.prox_theta)
        grads = apply_mask(grads, mask)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return step, opt


@functools.lru_cache(maxsize=16)
def cached_client_step(cfg: ModelConfig, fed: FedConfig):
    """Memoized ``make_client_step`` (no loss_kwargs — those can be
    unhashable): repeated simulator runs reuse the jitted step instead of
    re-tracing a fresh closure per run."""
    return make_client_step(cfg, fed)


def client_update(params_global, t: int, batches, cfg: ModelConfig,
                  fed: FedConfig, step=None, opt=None, mask=None,
                  num_iters: int | None = None):
    """Run H local iterations from the received global model.

    ``batches`` is an iterable of local data batches (length >= H).
    Returns (w_new, tau=t, losses).

    This is the legacy per-iteration dispatch loop (one jitted step + one
    ``float(loss)`` host sync per iteration). The compiled hot path lives
    in ``repro.core.fed_engine``: ``ClientRun`` for one client's scan,
    ``ClientRun.run_batch`` for many clients with per-client ``num_iters``
    (padded masked scan under vmap). This loop is kept as the parity
    oracle those programs are tested against — including per-client H^k,
    where the oracle is simply this loop called once per client.
    """
    if step is None:
        step, opt = make_client_step(cfg, fed)
    if mask is None:
        mask = trainable_mask(params_global, fed.trainable)
    params = params_global
    anchor = params_global
    opt_state = opt.init(params)
    losses = []
    H = num_iters if num_iters is not None else fed.local_iters_max
    for i, batch in zip(range(H), batches):
        params, opt_state, loss = step(params, opt_state, anchor, batch, mask)
        losses.append(float(loss))
    return params, t, losses
