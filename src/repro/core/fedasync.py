"""Asynchronous federated optimization (paper Algorithm 1).

Server: on receiving (w_new, τ) from any client at global epoch t,
    β_t = β · s(t - τ),   s(x) = (1 + x)^{-a}        (paper §V-C)
    w_t = (1 - β_t) · w_{t-1} + β_t · w_new

Client k: from the received global (w_t, t), runs H ∈ [H_min, H_max] local
SGD iterations on g_{w_t}(w; d) = l(w; d) + (θ/2)||w - w_t||².

Both halves are jitted pure functions; the asynchronous event order is
driven by core/simulator.py (or a real multi-pod launcher).

This module is the *reference* implementation: one jitted step per local
iteration, one server mix per receive. The compiled hot path lives in
``core/fed_engine.py`` — H iterations fuse into one ``lax.scan`` program,
concurrent dispatches with per-client H^k batch into one padded vmap
program (docs/fed_engine.md) — and is tested for float32 parity against
the loops here.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.optim import apply_mask, proximal_grad, sgd, trainable_mask
from repro.types import FedConfig, ModelConfig


def staleness_fn(a: float) -> Callable:
    """s(x) = (1+x)^{-a}; s(0)=1, monotonically decreasing (paper §IV-A)."""
    def s(x):
        return (1.0 + jnp.maximum(x, 0).astype(jnp.float32)) ** (-a)
    return s


def mixing_weight(fed: FedConfig, t, tau):
    return fed.mixing_beta * staleness_fn(fed.staleness_a)(t - tau)


@dataclass
class ServerState:
    params: Any
    t: int = 0                 # global epoch counter
    total_updates: int = 0


@jax.jit
def _mix(params, w_new, beta_t):
    return jax.tree_util.tree_map(
        lambda a, b: ((1.0 - beta_t) * a.astype(jnp.float32)
                      + beta_t * b.astype(jnp.float32)).astype(a.dtype),
        params, w_new)


def make_server_update(fed: FedConfig):
    """Jitted mixing update: (w_{t-1}, w_new, β_t) -> w_t.

    The mixing program is config-independent (β_t arrives as an argument),
    so every FedConfig shares ONE jitted function: ``server_receive``
    with ``mix=None`` used to build a fresh ``jax.jit`` wrapper on every
    receive, paying trace+compile for each update it applied.
    """
    return _mix


def server_receive(state: ServerState, w_new, tau: int, fed: FedConfig,
                   mix=None) -> ServerState:
    """One server step of Algorithm 1."""
    if mix is None:
        mix = make_server_update(fed)
    # staleness = global updates applied since the client grabbed the model;
    # s(0) = 1 when none intervened. Assumption 3 clamps at K.
    staleness = min(max(state.t - tau, 0), fed.max_staleness)
    beta_t = float(fed.mixing_beta
                   * (1.0 + staleness) ** (-fed.staleness_a))
    params = mix(state.params, w_new, jnp.float32(beta_t))
    return ServerState(params=params, t=state.t + 1,
                       total_updates=state.total_updates + 1)


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------

def make_client_step(cfg: ModelConfig, fed: FedConfig, loss_kwargs=None):
    """One proximal local SGD iteration, jitted.

    (params, opt_state, anchor, batch) -> (params, opt_state, loss)
    """
    loss_kwargs = dict(loss_kwargs or {})
    opt = sgd(fed.lr, fed.momentum, fed.weight_decay)

    def task_loss(params, batch):
        return registry.loss_fn(params, cfg, batch, **loss_kwargs)[0]

    @jax.jit
    def step(params, opt_state, anchor, batch, mask):
        loss, grads = jax.value_and_grad(task_loss)(params, batch)
        grads = proximal_grad(grads, params, anchor, fed.prox_theta)
        grads = apply_mask(grads, mask)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return step, opt


@functools.lru_cache(maxsize=16)
def cached_client_step(cfg: ModelConfig, fed: FedConfig):
    """Memoized ``make_client_step`` (no loss_kwargs — those can be
    unhashable): repeated simulator runs reuse the jitted step instead of
    re-tracing a fresh closure per run."""
    return make_client_step(cfg, fed)


def client_update(params_global, t: int, batches, cfg: ModelConfig,
                  fed: FedConfig, step=None, opt=None, mask=None,
                  num_iters: int | None = None):
    """Run H local iterations from the received global model.

    ``batches`` is an iterable of local data batches (length >= H).
    Returns (w_new, tau=t, losses).

    This is the legacy per-iteration dispatch loop (one jitted step + one
    ``float(loss)`` host sync per iteration). The compiled hot path lives
    in ``repro.core.fed_engine``: ``ClientRun`` for one client's scan,
    ``ClientRun.run_batch`` for many clients with per-client ``num_iters``
    (padded masked scan under vmap). This loop is kept as the parity
    oracle those programs are tested against — including per-client H^k,
    where the oracle is simply this loop called once per client.
    """
    if step is None:
        step, opt = make_client_step(cfg, fed)
    if mask is None:
        mask = trainable_mask(params_global, fed.trainable)
    params = params_global
    anchor = params_global
    opt_state = opt.init(params)
    losses = []
    H = num_iters if num_iters is not None else fed.local_iters_max
    for i, batch in zip(range(H), batches):
        params, opt_state, loss = step(params, opt_state, anchor, batch, mask)
        losses.append(float(loss))
    return params, t, losses
