"""Continuous-batching serving loop on the shared compile cache.

The paper's deployment target is per-device inference (Table V); a real
fleet serves *streams* of requests. This scheduler keeps a fixed pool of
decode slots; each slot holds one request's KV/SSM state and its own
position counter. New requests are admitted the moment a slot frees
(iteration-level scheduling) rather than waiting for a whole batch wave.

Compile discipline (core/compile_cache.py, shared with the fed engine):
prompts are padded into power-of-two *prefill buckets*
``bucket(P) = next_pow2(clamp(P, min_bucket, max_len))`` and every admit
tick prefills all newly admitted requests of a bucket as ONE vmapped
program of fixed shape ``(max_slots, bucket)`` — so a mixed-length request
stream compiles at most ``len(buckets)`` prefill programs instead of one
per distinct prompt length. A per-row length vector masks the padding:
attention pads are causally invisible and overwritten by decode before
they could be attended, the SSM recurrence treats pad steps as exact
no-ops (dt=0), and logits gather at each row's last real token — greedy
outputs are bit-identical to per-request serving (tested).

Decode runs per-layer-kind (``decode_mode="ring"``, the default): SWA
layers keep W-slot ring buffers (O(window) HBM per step, and ~W/max_len
the cache memory), full-attention layers attend against the first
``k_ext`` positions of their uniform cache where ``k_ext`` is the active
prefix bucketed on the same pow-2 ladder as prefill — so decode compiles
at most ``len(ladder)`` programs and reads O(window / active prefix) HBM
per step instead of streaming the whole ``(L, max_slots, max_len)``
cache. ``decode_mode="uniform"`` keeps the legacy full-cache decode as a
parity oracle. Per-slot positions come from ``jax.vmap`` over the batch
dim of the single-stream step — every family (dense / SWA / MoE / SSM /
hybrid) works in both modes. ``min_bucket=0`` keeps the legacy
per-request-length admission as a parity oracle (and the bench's
compile-count foil).

Ring-mode decode runs the attends and the SSM recurrence as fused Pallas
kernels by default (``decode_kernel="pallas"`` — see ``kernels/ops.py``:
ring attend, ladder-extent attend, SSD step; one HBM pass per cache,
score/update tensors never materialized). ``decode_kernel="einsum"``
keeps the PR-5 jnp decode as the parity oracle; uniform mode always uses
it. Greedy tokens are identical either way (tested per family).
"""
from __future__ import annotations

import itertools
import warnings
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.compile_cache import JitCache, bucket_for, bucket_ladder
from repro.models import lm, registry
from repro.types import ModelConfig

DECODE_MODES = ("ring", "uniform")
DECODE_KERNELS = ("pallas", "einsum")


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (P,) int32
    max_new: int
    eos_id: Optional[int] = None
    out: list = field(default_factory=list)
    slot: int = -1

    @property
    def done(self) -> bool:
        if len(self.out) >= self.max_new:
            return True
        return bool(self.out) and self.eos_id is not None \
            and self.out[-1] == self.eos_id


class ContinuousBatcher:
    """Fixed-slot continuous batching for any LM-family architecture.

    ``min_bucket`` > 0 (default) turns on bucketed prefill: same-tick
    admits run as one padded ``(max_slots, bucket)`` program per bucket,
    and ``prefill_compiles`` is bounded by ``len(self.buckets)``.
    ``min_bucket=0`` prefills each request alone at its exact length
    (one compile per distinct prompt length) — the parity oracle.

    ``decode_mode="ring"`` (default) decodes on per-layer-kind caches:
    W-slot ring buffers for SWA layers, a ladder-bucketed K-extent for
    full-attention layers (``decode_compiles`` bounded by
    ``len(self.decode_buckets)``). ``decode_mode="uniform"`` keeps the
    legacy full-cache decode — the parity oracle.

    ``decode_kernel="pallas"`` (default) fuses the ring-mode decode hot
    path into the Pallas decode kernels; ``"einsum"`` is the jnp parity
    oracle. Uniform mode ignores the flag (always einsum).
    """

    def __init__(self, params, cfg: ModelConfig, max_slots: int = 4,
                 max_len: int = 256, dtype=jnp.float32,
                 min_bucket: int = 8, decode_mode: str = "ring",
                 decode_kernel: str = "pallas"):
        if cfg.is_encdec or cfg.family == "resnet3d":
            raise ValueError(f"{cfg.family}: not a decoder-only server")
        if cfg.prefix_len:
            raise ValueError(
                f"{cfg.name}: prefix-embedding (VLM/audio) serving needs "
                "per-request prefix tensors, which Request does not carry")
        if decode_mode not in DECODE_MODES:
            raise ValueError(f"decode_mode {decode_mode!r} not in "
                             f"{DECODE_MODES}")
        if decode_kernel not in DECODE_KERNELS:
            raise ValueError(f"decode_kernel {decode_kernel!r} not in "
                             f"{DECODE_KERNELS}")
        self.decode_kernel = decode_kernel if decode_mode == "ring" \
            else "einsum"
        self.params, self.cfg = params, cfg
        self.max_slots, self.max_len = max_slots, max_len
        self.min_bucket = int(min_bucket)
        self.buckets = (bucket_ladder(self.min_bucket, max_len)
                        if self.min_bucket > 0 else ())
        self.decode_mode = decode_mode
        self.cache_dtype = dtype
        attn_free = cfg.family == "ssm"
        self._gl = () if attn_free else tuple(lm.global_layer_ids(cfg))
        self._wl = () if attn_free else tuple(lm.swa_layer_ids(cfg))
        if decode_mode == "ring":
            self.cache = registry.init_ring_cache(cfg, max_slots, max_len,
                                                  dtype)
            # full-attention layers key one decode program per K-extent
            # rung; SWA/SSM-only models decode as a single program
            self.decode_buckets = (bucket_ladder(max(self.min_bucket, 1),
                                                 max_len)
                                   if self._gl else ())
        else:
            self.cache = registry.init_cache(cfg, max_slots, max_len, dtype)
            self.decode_buckets = ()
        self.pos = np.zeros(max_slots, np.int32)        # next position
        self.last_tok = np.zeros(max_slots, np.int32)
        self.active: list[Optional[Request]] = [None] * max_slots
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        # {admit group size: count of prefill programs run with it} —
        # serving's mirror of the async simulator's SimResult.group_hist
        self.group_admits: dict = {}
        self.bucket_hist: dict = {}     # {bucket (or exact P): admits}
        self._rid = itertools.count()
        self._steps = 0
        self._jits = JitCache()
        self._decode_fns: dict = {}     # {k_ext: vmapped ring decode}
        self._decode_fn = (None if decode_mode == "ring"
                           else self._make_decode(0))

    def _make_decode(self, k_ext: int):
        """One vmapped decode: per-slot token + per-slot position. vmap
        consumes the cache's batch dim (in_axes=1); the single-stream step
        expects an explicit batch dim, so re-insert a size-1 one inside.
        ``k_ext`` is the static K-extent full-attention layers attend
        against in ring mode (one program per ladder rung)."""
        cfg, ring = self.cfg, self.decode_mode == "ring"
        kern = self.decode_kernel

        def one(params, token, cache, pos):
            cache = jax.tree_util.tree_map(
                lambda a: jnp.expand_dims(a, 1), cache)
            if ring:
                logits, cache = registry.decode_step_grouped(
                    params, cfg, token[None], cache, pos, k_ext=k_ext,
                    decode_kernel=kern)
            else:
                logits, cache = registry.decode_step(params, cfg,
                                                     token[None], cache, pos)
            cache = jax.tree_util.tree_map(lambda a: a[:, 0], cache)
            return logits, cache

        return jax.vmap(one, in_axes=(None, 0, 1, 0), out_axes=(0, 1))

    # -- compile accounting --------------------------------------------
    @property
    def prefill_compiles(self) -> int:
        """Distinct prefill programs traced. Bucketed admission bounds
        this by ``len(self.buckets)``; the per-request oracle pays one
        per distinct prompt length."""
        return self._jits.count("prefill")

    @property
    def decode_compiles(self) -> int:
        """Distinct decode programs traced. Ring mode bounds this by
        ``max(1, len(self.decode_buckets))`` (one per K-extent rung a
        stream actually reached); uniform mode compiles exactly one."""
        return self._jits.count("decode")

    @property
    def num_compiled(self) -> int:
        return self._jits.num_compiled

    # -- jitted entry points (shape-keyed in the shared JitCache) -------
    def _prefill_fn(self, params, tokens, lengths):
        """(B, S) right-padded tokens + (B,) true lengths -> per-row
        last-real-token logits and a cache of sequence capacity S. The
        cache is built inside the program, so each bucket allocates only
        its own length."""
        S = tokens.shape[1]
        cache = registry.init_cache(self.cfg, tokens.shape[0], S,
                                    self.cache_dtype)
        # q-chunking partitions query rows only (each row's softmax runs
        # against full K either way — bit-identical); power-of-two buckets
        # chunk at 64, exact odd lengths fall back to one block
        return registry.prefill(params, self.cfg, {"tokens": tokens}, cache,
                                lengths=lengths,
                                q_chunk=64 if S % 64 == 0 else S)

    def _install_fn(self, full, group, slots, lengths):
        """Scatter the first ``len(slots)`` rows of a group prefill cache
        into the server cache's slots — one program per (bucket, m) shape.
        Leaves whose trailing dims differ carry the sequence axis at dim 2
        (K/V: (L, B, S, kv, hd)); only their first ``bucket`` positions
        are written, the rest of the slot is causally dead anyway."""
        m = slots.shape[0]

        def leaf(f, g):
            g = g[:, :m].astype(f.dtype)
            if g.shape[2:] != f.shape[2:]:
                return f.at[:, slots, :g.shape[2]].set(g)
            return f.at[:, slots].set(g)

        return jax.tree_util.tree_map(leaf, full, group)

    def _install_ring_fn(self, full, group, slots, lengths):
        """Scatter a *uniform* group-prefill cache (L-leading K/V of the
        bucket's sequence extent) into the per-layer-kind server cache.

        Full-attention layers copy their bucket prefix as before.  SWA
        layers gather into ring layout per row (``lm.ring_source_positions``
        — the latest prompt position congruent to each slot mod W).  Slots
        whose position would be negative (prompt shorter than W) are
        ZEROED rather than left holding a clipped gather of position 0:
        decode masks them by construction (``ring_decode_attend``
        recomputes each slot's absolute position from ``pos`` and masks
        negatives), but an explicit zero keeps the cache state
        install-order independent and the masking testable
        (tests/test_serving.py::test_ring_install_short_prompt_slots)."""
        m = slots.shape[0]
        out = dict(full)
        for key in ("ssm_state", "conv_state"):
            if key in group:
                out[key] = full[key].at[:, slots].set(
                    group[key][:, :m].astype(full[key].dtype))
        if "k" in group:
            S_b = group["k"].shape[2]
            if self._gl:
                gi = jnp.asarray(self._gl)
                for src, dst in (("k", "k"), ("v", "v")):
                    g = group[src][gi][:, :m].astype(full[dst].dtype)
                    out[dst] = full[dst].at[:, slots, :S_b].set(g)
            if self._wl:
                W = full["k_win"].shape[2]
                p = lm.ring_source_positions(lengths[:m] - 1, W)
                take = jnp.clip(p, 0, S_b - 1)[None, :, :, None, None]
                wi = jnp.asarray(self._wl)
                written = (p >= 0)[None, :, :, None, None]
                for src, dst in (("k", "k_win"), ("v", "v_win")):
                    g = jnp.take_along_axis(
                        group[src][wi][:, :m], take, axis=2)
                    g = jnp.where(written, g, 0)
                    out[dst] = full[dst].at[:, slots].set(
                        g.astype(full[dst].dtype))
        return out

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new: int = 16, eos_id=None) -> int:
        """Queue one request. Rejects invalid requests *here*, with a
        ``ValueError``, so a bad submit can never reach ``_admit`` and
        kill the serving loop (the old in-loop ``assert`` discarded every
        valid in-flight request — and vanished under ``python -O``)."""
        prompt = np.asarray(prompt, np.int32)
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new} "
                             "(prefill itself emits the first token)")
        if prompt.ndim != 1:
            raise ValueError(f"prompt must be 1-D, got shape {prompt.shape}")
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if prompt.size + max_new > self.max_len:
            raise ValueError(
                f"request too long: len(prompt)={prompt.size} + "
                f"max_new={max_new} exceeds max_len={self.max_len}")
        req = Request(next(self._rid), prompt, max_new, eos_id)
        self.queue.append(req)
        return req.rid

    def _prefill_group(self, bucket: int, items):
        """One vmapped prefill for all (slot, request) pairs of a bucket,
        padded to the fixed (max_slots, bucket) program shape with dummy
        rows so group size never enters the compile key."""
        m = len(items)
        tokens = np.zeros((self.max_slots, bucket), np.int32)
        lengths = np.ones((self.max_slots,), np.int32)
        for j, (_, req) in enumerate(items):
            P = len(req.prompt)
            tokens[j, :P] = req.prompt
            lengths[j] = P
        logits, gcache = self._jits.call(
            "prefill", self._prefill_fn, (),
            (self.params, jnp.asarray(tokens), jnp.asarray(lengths)))
        # full-shape logits: _install reads rows [0, m) on host after the
        # argmax transfer, so eagerly slicing [:m] here would only add a
        # device dispatch per admit group
        self._install(gcache, items, logits, lengths[:m])
        self.group_admits[m] = self.group_admits.get(m, 0) + 1
        self.bucket_hist[bucket] = self.bucket_hist.get(bucket, 0) + 1

    def _prefill_one(self, slot: int, req: Request):
        """Parity oracle: exact-length, single-request prefill (compiles
        once per distinct prompt length)."""
        P = len(req.prompt)
        logits, c1 = self._jits.call(
            "prefill", self._prefill_fn, (),
            (self.params, jnp.asarray(req.prompt[None]),
             jnp.asarray([P], np.int32)))
        self._install(c1, [(slot, req)], logits,
                      np.asarray([P], np.int32))
        self.group_admits[1] = self.group_admits.get(1, 0) + 1
        self.bucket_hist[P] = self.bucket_hist.get(P, 0) + 1

    def _install(self, gcache, items, logits, lengths):
        slots = np.asarray([s for s, _ in items], np.int32)
        install = (self._install_ring_fn if self.decode_mode == "ring"
                   else self._install_fn)
        self.cache = self._jits.call(
            "install", install, (0,),
            (self.cache, gcache, jnp.asarray(slots),
             jnp.asarray(lengths, jnp.int32)))
        # argmax on device, one explicit transfer of B ints to host
        nxt = jax.device_get(jnp.argmax(logits, axis=-1)).astype(np.int32)
        for j, (slot, req) in enumerate(items):
            req.slot = slot
            req.out.append(int(nxt[j]))
            self.pos[slot] = int(lengths[j]) + self.cfg.prefix_len
            self.last_tok[slot] = nxt[j]
            self.active[slot] = req

    def _admit(self):
        free = [s for s in range(self.max_slots) if self.active[s] is None]
        take = min(len(free), len(self.queue))
        if not take:
            return
        reqs = [self.queue.pop(0) for _ in range(take)]
        if not self.buckets:
            for slot, req in zip(free, reqs):
                self._prefill_one(slot, req)
            return
        groups: dict = {}
        for slot, req in zip(free, reqs):
            b = bucket_for(len(req.prompt), self.min_bucket, self.max_len)
            groups.setdefault(b, []).append((slot, req))
        for b in sorted(groups):
            self._prefill_group(b, groups[b])

    def _retire(self):
        for slot, req in enumerate(self.active):
            if req is not None and req.done:
                self.completed.append(req)
                self.active[slot] = None

    # ------------------------------------------------------------------
    def _decode_k_ext(self, mask) -> int:
        """Static K-extent for this tick's full-attention decode: the
        largest active slot's ``pos + 1`` bucketed on the pow-2 ladder —
        so the traced programs are bounded by ``len(decode_buckets)``,
        and every active row's prefix fits (pad rows are ``k_len``-masked
        per slot, keeping the slice bit-identical to the full attend)."""
        if not self.decode_buckets:
            return 0
        need = int(self.pos[mask].max()) + 1
        return bucket_for(need, max(self.min_bucket, 1), self.max_len)

    def step(self) -> int:
        """One scheduler iteration: retire, admit, batched decode.
        Returns the number of active slots that decoded."""
        self._retire()
        self._admit()
        # a request can complete at admit time (max_new=1, or eos on the
        # prefill token): retire it before decode or it would overshoot
        self._retire()
        mask = np.array([r is not None for r in self.active])
        if not mask.any():
            return 0
        if self.decode_mode == "ring":
            k_ext = self._decode_k_ext(mask)
            if k_ext not in self._decode_fns:
                self._decode_fns[k_ext] = self._make_decode(k_ext)
            name, fn = ("decode", k_ext), self._decode_fns[k_ext]
        else:
            name, fn = "decode", self._decode_fn
        logits, self.cache = self._jits.call(
            name, fn, (2,),
            (self.params, jnp.asarray(self.last_tok), self.cache,
             jnp.asarray(self.pos)))
        # argmax on device, one explicit transfer of B ints per step; the
        # length-1 step axis is squeezed on host (an eager [:, 0, :] would
        # cost an extra device dispatch per decode step)
        nxt = jax.device_get(
            jnp.argmax(logits, axis=-1)).astype(np.int32)[:, 0]
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(nxt[slot]))
            self.pos[slot] += 1
            self.last_tok[slot] = nxt[slot]
        self._steps += 1
        return int(mask.sum())

    def pending(self) -> list:
        """Requests not yet completed: in-flight (slot order) + queued."""
        return [r for r in self.active if r is not None] + list(self.queue)

    def run(self, max_iters: int = 10_000) -> list:
        """Drive until queue + slots drain; returns completed requests.

        If ``max_iters`` runs out first, the leftover requests are NOT
        silently dropped: a ``RuntimeWarning`` reports how many are still
        queued / in flight, and they stay reachable via ``pending()`` (a
        later ``run()`` resumes them)."""
        for _ in range(max_iters):
            if not self.queue and all(r is None for r in self.active):
                break
            if self.step() == 0 and not self.queue:
                break
            self._retire()
        self._retire()
        left = self.pending()
        if left:
            n_flight = sum(r is not None for r in self.active)
            warnings.warn(
                f"run(max_iters={max_iters}) exhausted with "
                f"{len(left) - n_flight} queued + {n_flight} in-flight "
                "requests unfinished — they remain in pending() and a "
                "further run() resumes them", RuntimeWarning,
                stacklevel=2)
        return sorted(self.completed, key=lambda r: r.rid)


def generate_single(params, cfg: ModelConfig, prompt, max_new: int,
                    max_len: int = 256, dtype=jnp.float32) -> list:
    """Reference single-request greedy generation (parity oracle)."""
    cache = registry.init_cache(cfg, 1, max_len, dtype)
    logits, cache = registry.prefill(
        params, cfg, {"tokens": jnp.asarray(np.asarray(prompt)[None],
                                            jnp.int32)}, cache, q_chunk=64)
    out = [int(jnp.argmax(logits, axis=-1)[0])]
    pos = len(prompt) + cfg.prefix_len
    for _ in range(max_new - 1):
        logits, cache = registry.decode_step(
            params, cfg, jnp.asarray([out[-1]], jnp.int32), cache,
            jnp.int32(pos))
        out.append(int(jnp.argmax(logits, axis=-1)[0]))
        pos += 1
    return out
