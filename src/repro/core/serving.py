"""Continuous-batching serving loop (slot-based, iteration-level admission).

The paper's deployment target is per-device inference (Table V); a real
fleet serves *streams* of requests. This scheduler keeps a fixed pool of
decode slots; each slot holds one request's KV/SSM state and its own
position counter. New requests are admitted the moment a slot frees
(iteration-level scheduling) rather than waiting for a whole batch wave.

Per-slot positions come from ``jax.vmap`` over the batch dim of the
existing single-stream ``decode_step`` — every family (dense / SWA / MoE /
SSM / hybrid / VLM) works unchanged, and greedy outputs are bit-identical
to running each request alone (tested).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.types import ModelConfig


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (P,) int32
    max_new: int
    eos_id: Optional[int] = None
    out: list = field(default_factory=list)
    slot: int = -1

    @property
    def done(self) -> bool:
        if len(self.out) >= self.max_new:
            return True
        return bool(self.out) and self.eos_id is not None \
            and self.out[-1] == self.eos_id


class ContinuousBatcher:
    """Fixed-slot continuous batching for any LM-family architecture."""

    def __init__(self, params, cfg: ModelConfig, max_slots: int = 4,
                 max_len: int = 256, dtype=jnp.float32):
        if cfg.is_encdec or cfg.family == "resnet3d":
            raise ValueError(f"{cfg.family}: not a decoder-only server")
        self.params, self.cfg = params, cfg
        self.max_slots, self.max_len = max_slots, max_len
        self.cache = registry.init_cache(cfg, max_slots, max_len, dtype)
        self.pos = np.zeros(max_slots, np.int32)        # next position
        self.last_tok = np.zeros(max_slots, np.int32)
        self.active: list[Optional[Request]] = [None] * max_slots
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self._rid = itertools.count()
        self._steps = 0

        # one vmapped decode: per-slot token + per-slot position. vmap
        # consumes the cache's batch dim (in_axes=1); decode_step expects an
        # explicit batch dim, so re-insert a size-1 one inside.
        def one(params, token, cache, pos):
            cache = jax.tree_util.tree_map(
                lambda a: jnp.expand_dims(a, 1), cache)
            logits, cache = registry.decode_step(params, cfg, token[None],
                                                 cache, pos)
            cache = jax.tree_util.tree_map(lambda a: a[:, 0], cache)
            return logits, cache

        self._decode = jax.jit(jax.vmap(
            one, in_axes=(None, 0, 1, 0), out_axes=(0, 1)))
        self._prefill = jax.jit(
            lambda params, batch, cache: registry.prefill(
                params, cfg, batch, cache, q_chunk=64))

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new: int = 16, eos_id=None) -> int:
        req = Request(next(self._rid), np.asarray(prompt, np.int32),
                      max_new, eos_id)
        self.queue.append(req)
        return req.rid

    def _admit(self):
        for slot in range(self.max_slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            req.slot = slot
            P = len(req.prompt)
            assert P + req.max_new <= self.max_len, "request too long"
            # prefill this request alone (B=1) and install into the slot
            c1 = registry.init_cache(self.cfg, 1, self.max_len,
                                     jax.tree_util.tree_leaves(
                                         self.cache)[0].dtype)
            logits, c1 = self._prefill(
                self.params, {"tokens": jnp.asarray(req.prompt[None])}, c1)
            self.cache = jax.tree_util.tree_map(
                lambda full, one: full.at[:, slot].set(one[:, 0]),
                self.cache, c1)
            nxt = int(jnp.argmax(logits, axis=-1)[0])
            req.out.append(nxt)
            self.pos[slot] = P + self.cfg.prefix_len
            self.last_tok[slot] = nxt
            self.active[slot] = req

    def _retire(self):
        for slot, req in enumerate(self.active):
            if req is not None and req.done:
                self.completed.append(req)
                self.active[slot] = None

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One scheduler iteration: retire, admit, batched decode.
        Returns the number of active slots that decoded."""
        self._retire()
        self._admit()
        mask = np.array([r is not None for r in self.active])
        if not mask.any():
            return 0
        logits, self.cache = self._decode(
            self.params, jnp.asarray(self.last_tok), self.cache,
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1), np.int32)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            req.out.append(int(nxt[slot]))
            self.pos[slot] += 1
            self.last_tok[slot] = nxt[slot]
        self._steps += 1
        return int(mask.sum())

    def run(self, max_iters: int = 10_000) -> list:
        """Drive until queue + slots drain; returns completed requests."""
        for _ in range(max_iters):
            if not self.queue and all(r is None for r in self.active):
                break
            if self.step() == 0 and not self.queue:
                break
            self._retire()
        self._retire()
        return sorted(self.completed, key=lambda r: r.rid)


def generate_single(params, cfg: ModelConfig, prompt, max_new: int,
                    max_len: int = 256, dtype=jnp.float32) -> list:
    """Reference single-request greedy generation (parity oracle)."""
    cache = registry.init_cache(cfg, 1, max_len, dtype)
    logits, cache = registry.prefill(
        params, cfg, {"tokens": jnp.asarray(np.asarray(prompt)[None],
                                            jnp.int32)}, cache, q_chunk=64)
    out = [int(jnp.argmax(logits, axis=-1)[0])]
    pos = len(prompt) + cfg.prefix_len
    for _ in range(max_new - 1):
        logits, cache = registry.decode_step(
            params, cfg, jnp.asarray([out[-1]], jnp.int32), cache,
            jnp.int32(pos))
        out.append(int(jnp.argmax(logits, axis=-1)[0]))
        pos += 1
    return out
