"""Convergence-bound evaluator for the paper's Theorem (§IV-B).

After E global updates,

    min_t E||∇F(w_t)||² ≤  E[F(w_0) - F(w_E)] / (β η ε E H_min)
                         + O(η λ³ H_min² / ε)           (local drift)
                         + O(β K λ / ε)                 (staleness, asymptotic)
                         + O(η K² λ² H_min / ε)
                         + O(β² η K² λ² H_min / ε)

and with η = 1/√E the bound → O(βKλ/ε) as E → ∞. The O(·) constants involve
B1, B2 (Assumption 4); we expose them explicitly so the bound is computable
and its monotonicities testable.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.types import FedConfig


@dataclass(frozen=True)
class BoundInputs:
    E: int                  # global epochs
    beta: float             # mixing β
    eta: float              # learning rate η
    eps: float              # ε from the theorem
    K: int                  # max staleness (Assumption 3)
    lam: float              # imbalance ratio λ = H_max / H_min
    H_min: int
    F0_minus_FE: float      # E[F(w_0) - F(w_E)]
    B1: float = 1.0         # ||∇l|| bound
    B2: float = 1.0         # ||∇g|| bound

    @staticmethod
    def from_fed(fed: FedConfig, E: int | None = None,
                 F0_minus_FE: float = 1.0, eps: float = 1.0,
                 B1: float = 1.0, B2: float = 1.0) -> "BoundInputs":
        return BoundInputs(
            E=E if E is not None else fed.global_epochs,
            beta=fed.mixing_beta, eta=fed.lr, eps=eps,
            K=fed.max_staleness, lam=fed.imbalance_ratio,
            H_min=fed.local_iters_min, F0_minus_FE=F0_minus_FE,
            B1=B1, B2=B2)


def bound_terms(b: BoundInputs) -> dict:
    """The five terms of the bound (with explicit B1/B2 constants)."""
    t0 = b.F0_minus_FE / (b.beta * b.eta * b.eps * b.E * b.H_min)
    t1 = b.eta * b.lam ** 3 * b.H_min ** 2 * b.B2 ** 2 / b.eps
    t2 = b.beta * b.K * b.lam * b.B1 * b.B2 / b.eps
    t3 = b.eta * b.K ** 2 * b.lam ** 2 * b.H_min * b.B2 ** 2 / b.eps
    t4 = b.beta ** 2 * b.eta * b.K ** 2 * b.lam ** 2 * b.H_min \
        * b.B2 ** 2 / b.eps
    return {"optimality": t0, "local_drift": t1, "staleness": t2,
            "staleness_sq": t3, "mixing_sq": t4}


def bound(b: BoundInputs) -> float:
    return sum(bound_terms(b).values())


def asymptotic_bound(b: BoundInputs) -> float:
    """lim_{E→∞} with η = 1/√E: O(βKλ/ε) — the only surviving term."""
    return b.beta * b.K * b.lam * b.B1 * b.B2 / b.eps


def theta_condition(theta: float, mu: float, eps: float, B2: float,
                    drift_sq: float) -> bool:
    """Theorem precondition: θ > μ and
    -(1+2θ+ε)B2² + (θ² - θ/2)·||w_{τ,h-1} - w_τ||² ≥ 0."""
    if theta <= mu:
        return False
    lhs = -(1.0 + 2.0 * theta + eps) * B2 ** 2 \
        + (theta ** 2 - theta / 2.0) * drift_sq
    return lhs >= 0.0


def min_theta(mu: float, eps: float, B2: float, drift_sq: float,
              hi: float = 1e6) -> float:
    """Smallest θ satisfying the precondition (bisection; math-only)."""
    if drift_sq <= 0:
        return math.inf
    lo = max(mu, 0.5) + 1e-9
    if not theta_condition(hi, mu, eps, B2, drift_sq):
        return math.inf
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if theta_condition(mid, mu, eps, B2, drift_sq):
            hi = mid
        else:
            lo = mid
    return hi


def lr_schedule_for_asymptotic(E: int) -> float:
    """The theorem's η = 1/√E choice."""
    return 1.0 / math.sqrt(E)
