"""Shared static-shape compile cache + bucketing for bounded-compile serving.

Both halves of the system live or die by the same discipline on embedded
hardware: every distinct program *shape* costs an XLA compile, so the hot
path must funnel its dynamic quantities either into traced arguments (the
fed engine's H^k iteration vector) or into a small static ladder of padded
shapes (serving's prefill buckets).  This module holds the two shared
pieces:

``JitCache``
    The per-engine pool of ``jax.jit`` wrappers previously private to
    ``core.fed_engine`` (``_JitCache``).  Entries are keyed by
    ``(entry point name, donated argnums)``; within an entry jax's own
    shape-keyed cache does the ``(H, trainable)``-style static-shape
    keying, and ``num_compiled`` / ``count(name)`` read the true number of
    traced programs back out of it.  Donation variants compile separately
    and are built lazily, so an engine that never donates never pays the
    extra trace.

Bucketing helpers
    ``bucket_for(P) = next_pow2(clamp(P, min_bucket, max_len))`` (capped
    at ``max_len`` so a non-power-of-two cap still bounds the ladder) maps
    a prompt length to the padded prefill length it compiles under;
    ``bucket_ladder`` enumerates the full ladder, whose size — not the
    number of distinct prompt lengths — bounds serving's prefill compile
    count.

See docs/serving.md and docs/fed_engine.md for how each subsystem keys
into the cache.
"""
from __future__ import annotations

import warnings

import jax


class JitCache:
    """Pool of jit wrappers keyed by (entry point, donated argnums).

    Donation variants compile separately, so they are built lazily — an
    engine that never donates never pays the extra trace.  Integer batch
    leaves (LM tokens) can never alias the float outputs; XLA's "donated
    buffers were not usable" note for them is suppressed, it is
    informational and expected.

    Distinct entry points must be distinct callables: jax's executable
    cache (what ``_cache_size`` reads) is shared across jit wrappers of
    the same Python function, so two entries wrapping one function would
    double-count each other's shapes.

    Compile counts prefer jax's own ``_cache_size()`` (the true traced-
    program count, including retraces our key can't see) but that is a
    private jit internal; every ``call`` also records the argument
    shape/dtype signature, so if a jax release drops or renames the
    internal the counts degrade to the recorded-signature count instead
    of raising from every compile-count assertion at once.
    """

    def __init__(self):
        self._jits: dict = {}
        self._seen: dict = {}     # key -> set of arg shape/dtype signatures

    @staticmethod
    def _signature(args) -> tuple:
        return tuple(
            (getattr(leaf, "shape", ()),
             str(getattr(leaf, "dtype", type(leaf).__name__)))
            for leaf in jax.tree_util.tree_leaves(args))

    def call(self, name, fn, donate: tuple, args):
        key = (name, donate)
        if key not in self._jits:
            self._jits[key] = jax.jit(fn, donate_argnums=donate)
            self._seen[key] = set()
        self._seen[key].add(self._signature(args))
        if not donate:
            return self._jits[key](*args)
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            return self._jits[key](*args)

    def _entry_size(self, key) -> int:
        """Traced programs for one (entry point, donate) pool entry, with
        the recorded-signature fallback when the private API is gone."""
        try:
            return int(self._jits[key]._cache_size())
        except Exception:
            return len(self._seen.get(key, ()))

    @property
    def num_compiled(self) -> int:
        """Distinct programs actually traced across every entry point."""
        return sum(self._entry_size(key) for key in self._jits)

    def count(self, name) -> int:
        """Traced programs for one entry point (every shape it compiled
        under, summed over donation variants).  ``name`` matches an entry
        whose key is either ``name`` itself or a tuple starting with it
        (e.g. ``("unstack", n)`` or ``("decode", k_ext)``)."""
        return sum(
            self._entry_size((n, d)) for (n, d) in self._jits
            if n == name or (isinstance(n, tuple) and n and n[0] == name))


# ---------------------------------------------------------------------------
# Prefill-length bucketing
# ---------------------------------------------------------------------------

def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    if n < 1:
        raise ValueError(f"next_pow2 needs n >= 1, got {n}")
    return 1 << (n - 1).bit_length()


def bucket_for(P: int, min_bucket: int, max_len: int) -> int:
    """Padded prefill length for a prompt of length P:
    ``next_pow2(clamp(P, min_bucket, max_len))``, capped at ``max_len``
    (the cache's sequence capacity) when that is not itself a power of
    two.  P must fit the cache: P <= max_len."""
    if P < 1:
        raise ValueError(f"prompt length must be >= 1, got {P}")
    if P > max_len:
        raise ValueError(f"prompt length {P} exceeds max_len {max_len}")
    return min(next_pow2(max(min(P, max_len), min_bucket)), max_len)


def bucket_ladder(min_bucket: int, max_len: int) -> tuple:
    """Every bucket ``bucket_for`` can produce, ascending.  Its length is
    the compile-count bound for bucketed prefill: one program per rung,
    however many distinct prompt lengths arrive."""
    ladder = []
    b = next_pow2(max(1, min_bucket))
    while b < max_len:
        ladder.append(b)
        b *= 2
    ladder.append(max_len)
    return tuple(ladder)
