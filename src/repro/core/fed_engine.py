"""Compiled client-execution engine for the federated hot path.

The legacy path (``fedasync.client_update`` / ``fedavg.fedavg_round_loop``)
dispatches one jitted ``step(...)`` per local iteration and host-syncs
``float(loss)`` after each — at simulator scale the fleet is dispatch-bound,
not compute-bound. This module collapses the H local proximal-SGD iterations
into a single ``jax.lax.scan`` over a pre-stacked batch pytree (zero
per-iteration host syncs) and, for synchronous rounds, runs *all* clients as
one batched program with ``jax.vmap`` (the global anchor broadcasts; the
per-client batch stacks carry a leading client axis).

Compilation is cached per ``(H, trainable)``: the simulator assigns each
device a static local-iteration budget H^k ∈ [H_min, H_max], so a
heterogeneous fleet triggers at most ``H_max - H_min + 1`` compiles and then
runs compile-free. The legacy loop remains in place as a parity oracle
(tests/test_fed_engine.py checks float32 agreement).
"""
from __future__ import annotations

from typing import Any, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.optim import apply_mask, proximal_grad, sgd, trainable_mask
from repro.types import FedConfig, ModelConfig


def stack_client_batches(client_batch_stacks: Sequence[Any]):
    """Stack per-client batch stacks (each leaf (H, ...)) into one pytree
    with a leading client axis (n_clients, H, ...) for the vmap round.

    All clients must share the same H and batch shapes (homogeneous sync
    round); raises ValueError otherwise so callers can fall back to the
    per-client loop.
    """
    if not client_batch_stacks:
        raise ValueError("no client batch stacks")
    shapes = [
        tuple(l.shape for l in jax.tree_util.tree_leaves(s))
        for s in client_batch_stacks
    ]
    if any(s != shapes[0] for s in shapes[1:]):
        raise ValueError(
            f"heterogeneous client batch stacks {shapes}; the vmap round "
            "needs a homogeneous fleet — use the per-client loop instead")
    return jax.tree_util.tree_map(
        lambda *leaves: np.stack(leaves), *client_batch_stacks)


def _batch_len(stacked) -> int:
    return int(jax.tree_util.tree_leaves(stacked)[0].shape[0])


class ClientRun:
    """Scan-compiled local training: H proximal SGD iterations in one call.

    ``engine(params_global, stacked, mask=None)`` -> ``(w_new, losses)``
    where ``stacked`` is a batch pytree with leading axis H (see
    ``repro.data.stack_batches``) and ``losses`` is a device array of shape
    (H,) — the only host sync the caller pays is reading it.
    """

    def __init__(self, cfg: ModelConfig, fed: FedConfig, loss_kwargs=None):
        self.cfg = cfg
        self.fed = fed
        self.loss_kwargs = dict(loss_kwargs or {})
        self.opt = sgd(fed.lr, fed.momentum, fed.weight_decay)
        self._jit_run = jax.jit(self._run)

    # -- pure (unjitted) core, reused by the vmap round ------------------
    def _task_loss(self, params, batch):
        return registry.loss_fn(params, self.cfg, batch,
                                **self.loss_kwargs)[0]

    def _run(self, params_global, stacked, mask):
        anchor = params_global

        def body(carry, batch):
            params, opt_state = carry
            loss, grads = jax.value_and_grad(self._task_loss)(params, batch)
            grads = proximal_grad(grads, params, anchor, self.fed.prox_theta)
            grads = apply_mask(grads, mask)
            params, opt_state = self.opt.update(grads, opt_state, params)
            return (params, opt_state), loss

        init = (params_global, self.opt.init(params_global))
        (w_new, _), losses = jax.lax.scan(body, init, stacked)
        return w_new, losses

    @property
    def num_compiled(self) -> int:
        """Distinct programs actually traced: H is the scan length (a
        static shape), so the jit wrapper compiles once per distinct H
        (trainable is fixed per engine; see ``_engine_key``) and then
        dispatches compile-free."""
        return self._jit_run._cache_size()

    def __call__(self, params_global, stacked, mask=None):
        if mask is None:
            mask = trainable_mask(params_global, self.fed.trainable)
        return self._jit_run(params_global, stacked, mask)


_ENGINE_CACHE: dict = {}
_ENGINE_CACHE_MAX = 32      # FIFO-bounded: engines hold compiled executables


def _engine_key(kind: str, cfg: ModelConfig, fed: FedConfig, loss_kwargs):
    """Cache key over the fields that affect the compiled client program.

    Server-side knobs (mixing_beta, staleness_a, ...) don't — two sweeps
    differing only in staleness must share compiled engines.
    """
    lk = tuple(sorted((loss_kwargs or {}).items()))
    key = (kind, cfg, fed.lr, fed.momentum, fed.weight_decay,
           fed.prox_theta, fed.trainable, lk)
    try:
        hash(key)
    except TypeError:
        return None
    return key


def _cached_engine(kind, cfg, fed, loss_kwargs, build):
    key = _engine_key(kind, cfg, fed, loss_kwargs)
    if key is None:                       # unhashable loss_kwargs
        return build()
    if key not in _ENGINE_CACHE:
        while len(_ENGINE_CACHE) >= _ENGINE_CACHE_MAX:
            _ENGINE_CACHE.pop(next(iter(_ENGINE_CACHE)))
        _ENGINE_CACHE[key] = build()
    return _ENGINE_CACHE[key]


def make_client_run(cfg: ModelConfig, fed: FedConfig,
                    loss_kwargs=None) -> ClientRun:
    """The scan engine replacing per-iteration ``step(...)`` dispatch.

    Memoized on the client-relevant config fields so repeated simulator
    runs (hyperparameter sweeps, benchmarks) reuse compiled programs.
    """
    return _cached_engine("client", cfg, fed, loss_kwargs,
                          lambda: ClientRun(cfg, fed, loss_kwargs))


class SyncRound:
    """vmap-over-clients FedAvg round: one batched program per round.

    ``round(params_global, client_stacks, weights, mask=None)`` ->
    ``(new_global, losses (n_clients, H))``. ``client_stacks`` is either a
    sequence of per-client stacked batch pytrees (stacked here) or an
    already client-stacked pytree with leading (n_clients, H) axes.
    """

    def __init__(self, cfg: ModelConfig, fed: FedConfig, loss_kwargs=None):
        # share the memoized ClientRun (it is stateless): async dispatches
        # and the sync round's inner scan then reuse one trace cache
        self.client = make_client_run(cfg, fed, loss_kwargs)
        self.fed = fed
        self._jit_rnd = jax.jit(self._rnd)

    def _rnd(self, params_global, stacked_clients, weights, mask):
        # anchor (and mask) broadcast; batch stacks are per-client
        w_news, losses = jax.vmap(
            lambda s: self.client._run(params_global, s, mask)
        )(stacked_clients)
        new = jax.tree_util.tree_map(
            lambda l, p: jnp.einsum(
                "c,c...->...", weights,
                l.astype(jnp.float32)).astype(p.dtype),
            w_news, params_global)
        return new, losses

    @property
    def num_compiled(self) -> int:
        """Distinct traced programs — one per (n_clients, H) shape."""
        return self._jit_rnd._cache_size()

    def __call__(self, params_global, client_stacks, weights=None,
                 mask=None):
        if isinstance(client_stacks, (list, tuple)):
            client_stacks = stack_client_batches(client_stacks)
        n = int(jax.tree_util.tree_leaves(client_stacks)[0].shape[0])
        if weights is None:
            weights = jnp.full((n,), 1.0 / n, jnp.float32)
        else:
            weights = jnp.asarray(weights, jnp.float32)
        if mask is None:
            mask = trainable_mask(params_global, self.fed.trainable)
        return self._jit_rnd(params_global, client_stacks, weights, mask)


def make_sync_round(cfg: ModelConfig, fed: FedConfig,
                    loss_kwargs=None) -> SyncRound:
    """The vmap engine replacing fedavg's per-client Python loop.

    Memoized like ``make_client_run``.
    """
    return _cached_engine("sync", cfg, fed, loss_kwargs,
                          lambda: SyncRound(cfg, fed, loss_kwargs))
