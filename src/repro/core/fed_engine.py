"""Compiled client-execution engine for the federated hot path.

The legacy path (``fedasync.client_update`` / ``fedavg.fedavg_round_loop``)
dispatches one jitted ``step(...)`` per local iteration and host-syncs
``float(loss)`` after each — at simulator scale the fleet is dispatch-bound,
not compute-bound. This module collapses the H local proximal-SGD iterations
into a single ``jax.lax.scan`` over a pre-stacked batch pytree (zero
per-iteration host syncs) and, for synchronous rounds, runs *all* clients as
one batched program with ``jax.vmap`` (the global anchor broadcasts; the
per-client batch stacks carry a leading client axis).

Heterogeneous fleets — the paper's whole point: each device k gets its own
local-iteration budget H^k ∈ [H_min, H_max] — batch through the *padded*
path: every client's batch stack is zero-padded to a common H_max
(``pad_client_batches``) and a per-client iteration count threads through
the scan body as a mask; steps with index ≥ H^k are identity on the
(params, opt_state) carry and emit NaN losses. H^k arrives as a *traced*
int32 vector, so the compile cache holds ONE entry per round shape
``(n_clients, H_max, batch...)`` instead of one per distinct H — a fleet
drawing H^k from [H_min, H_max] compiles once and runs compile-free.

``ShardedSyncRound`` additionally splits the client axis of the padded
round over a device mesh (``launch.mesh.make_fleet_mesh``,
``sharding.specs.fed_round_specs``) with ``shard_map``: each shard runs its
local clients' scans and the weighted average reduces with ``psum``.

Buffer donation (``jax.jit(..., donate_argnums)``): callers that own their
inputs hand them to XLA for in-place reuse. The engine donates the batch
stacks whenever it built them itself, and — on explicit
``donate_params=True`` — the old global params, whose buffers the new
global aliases exactly (the scan carry starts from them); ``run_sync``
uses this from the second round on, when the previous round's output is
provably dead. See docs/fed_engine.md.

The jit pool itself (``compile_cache.JitCache``) is shared with the
serving stack: serving's bucketed prefill keys into the same
static-shape cache machinery this engine keys ``(H, trainable)`` round
shapes into. See core/compile_cache.py.

The *algorithm* inside the programs — the per-iteration update rule, the
client-carried state, the server fold, the wire format — is pluggable:
every engine takes ``algorithm=`` (a ``core.algorithms.FedAlgorithm``,
default ``FedProx()``, bit-identical to the pre-refactor behavior). A
stateless algorithm keeps the legacy entry-point outputs
``(w_new, losses)``; a stateful one (SCAFFOLD variates, low-rank
capacities) threads ``(server_ctx, states)`` through the same programs —
appended at the END of every jitted argument tuple so the donation
argnums (params, batch stacks) stay put — and returns
``(w_new, new_state, msg, losses)`` per client / ``(new_global, new_ctx,
new_states, losses)`` per round. Algorithm identity folds into the
engine memo key via ``cache_key()``; traced per-client quantities (H^k,
low-rank capacity) stay out of it, keeping ONE compiled program per
``(round shape, algorithm)``.

The legacy loop remains in place as a parity oracle
(tests/test_fed_engine.py checks float32 agreement).
"""
from __future__ import annotations

from typing import Any, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import algorithms
from repro.core.compile_cache import JitCache as _JitCache
from repro.models import registry
from repro.optim import sgd, trainable_mask
from repro.types import FedConfig, ModelConfig


def stack_client_batches(client_batch_stacks: Sequence[Any]):
    """Stack per-client batch stacks (each leaf (H, ...)) into one pytree
    with a leading client axis (n_clients, H, ...) for the vmap round.

    All clients must share the same H and batch shapes (homogeneous sync
    round); raises ValueError otherwise — heterogeneous fleets batch
    through ``pad_client_batches``, which pads per-client H to a common
    H_max and returns the iteration mask for the padded scan.
    """
    if not client_batch_stacks:
        raise ValueError("no client batch stacks")
    shapes = [
        tuple(l.shape for l in jax.tree_util.tree_leaves(s))
        for s in client_batch_stacks
    ]
    if any(s != shapes[0] for s in shapes[1:]):
        raise ValueError(
            f"heterogeneous client batch stacks {shapes}; use "
            "pad_client_batches to pad per-client H to a common H_max and "
            "run the padded masked-scan round (one batched program)")
    return jax.tree_util.tree_map(
        lambda *leaves: np.stack(leaves), *client_batch_stacks)


def pad_client_batches(client_batch_stacks: Sequence[Any],
                       H_max: int | None = None):
    """Pad per-client batch stacks (each leaf (H^k, ...)) to a common H_max
    and stack to (n_clients, H_max, ...).

    Returns ``(stacked, iters)`` where ``iters`` is an int32 array of the
    true per-client iteration counts H^k — the scan mask. Padding is
    zeros: the masked scan computes a (discarded) step on pad batches, so
    their contents never reach the model update. Clients may be empty
    (H^k = 0, ``None`` or zero-length stacks) as long as one client has a
    batch to take shapes from. Trailing (per-batch) shapes and dtypes must
    agree across clients; raises ValueError otherwise — that raggedness
    needs the per-client fallback, not padding.
    """
    if not client_batch_stacks:
        raise ValueError("no client batch stacks")
    lens = [(0 if s is None else
             int(jax.tree_util.tree_leaves(s)[0].shape[0])
             if jax.tree_util.tree_leaves(s) else 0)
            for s in client_batch_stacks]
    ref = next((s for s, h in zip(client_batch_stacks, lens) if h), None)
    if ref is None:
        raise ValueError("all clients empty; nothing to pad from")
    if H_max is None:
        H_max = max(lens)
    if max(lens) > H_max:
        raise ValueError(f"client iteration counts {lens} exceed "
                         f"H_max={H_max}")
    ref_flat, treedef = jax.tree_util.tree_flatten(ref)
    trailing = [(tuple(l.shape[1:]), np.asarray(l).dtype) for l in ref_flat]

    padded = []
    for s, h in zip(client_batch_stacks, lens):
        if h == 0:
            flat = [np.zeros((H_max,) + shp, dt) for shp, dt in trailing]
            padded.append(jax.tree_util.tree_unflatten(treedef, flat))
            continue
        if jax.tree_util.tree_structure(s) != treedef:
            raise ValueError(
                "client batch stacks disagree on pytree structure (keys); "
                "matching leaf shapes cannot substitute for matching keys")
        flat = [np.asarray(l) for l in jax.tree_util.tree_leaves(s)]
        if [(tuple(l.shape[1:]), l.dtype) for l in flat] != trailing:
            raise ValueError(
                "client batch stacks disagree on per-batch shapes/dtypes; "
                "padding only evens out iteration counts — use the "
                "per-client fallback for truly ragged batches")
        pad = H_max - h
        if pad:
            flat = [np.concatenate(
                [l, np.zeros((pad,) + l.shape[1:], l.dtype)]) for l in flat]
        padded.append(jax.tree_util.tree_unflatten(treedef, flat))
    stacked = jax.tree_util.tree_map(
        lambda *leaves: np.stack(leaves), *padded)
    return stacked, np.asarray(lens, np.int32)


def _batch_len(stacked) -> int:
    return int(jax.tree_util.tree_leaves(stacked)[0].shape[0])


def _full_iters(stacked_clients):
    """(n,) iteration vector for 'every client runs the whole stack'."""
    n, H = jax.tree_util.tree_leaves(stacked_clients)[0].shape[:2]
    return np.full((int(n),), int(H), np.int32)


def _pad_H(fed: FedConfig, client_stacks) -> int:
    """Pad target: the config's H_max, stretched if a caller handed in a
    longer stack — constant across rounds, so the padded program's shape
    (and compile-cache entry) stays stable whatever H^k is drawn."""
    return max(fed.local_iters_max,
               max((_batch_len(s) for s in client_stacks
                    if s is not None), default=0))


class ClientRun:
    """Scan-compiled local training: H proximal SGD iterations in one call.

    ``engine(params_global, stacked, mask=None)`` -> ``(w_new, losses)``
    where ``stacked`` is a batch pytree with leading axis H (see
    ``repro.data.stack_batches``) and ``losses`` is a device array of shape
    (H,) — the only host sync the caller pays is reading it.

    ``run_batch(params_global, client_stacks, iters)`` is the padded
    batched variant: many clients with *different* H^k run as one vmapped
    masked-scan program, returning per-client ``(w_news, losses)`` with
    leading client axes (no aggregation — the async simulator uses this to
    batch concurrent dispatches: the fleet-wide kickoff and, with a
    positive ``simulator.run_async(window=...)``, every steady-state
    re-dispatch burst; ``SyncRound`` adds the weighted average). Burst
    sizes m ≤ n_clients each compile once per (m, H_max) shape, so a
    windowed run is compile-free after its first pass over the sizes.
    """

    def __init__(self, cfg: ModelConfig, fed: FedConfig, loss_kwargs=None,
                 algorithm=None):
        self.cfg = cfg
        self.fed = fed
        self.loss_kwargs = dict(loss_kwargs or {})
        self.algorithm = (algorithm if algorithm is not None
                          else algorithms.FedProx())
        self.opt = sgd(fed.lr, fed.momentum, fed.weight_decay)
        self._jits = _JitCache()

    # -- pure (unjitted) core, reused by the vmap round ------------------
    def _task_loss(self, params, batch):
        return registry.loss_fn(params, self.cfg, batch,
                                **self.loss_kwargs)[0]

    def _ctx(self, anchor, mask, server_ctx):
        return algorithms.StepCtx(jax.value_and_grad(self._task_loss),
                                  self.opt, anchor, mask, server_ctx,
                                  self.fed)

    def _run(self, params_global, stacked, mask, server_ctx=(), state=()):
        alg = self.algorithm
        ctx = self._ctx(params_global, mask, server_ctx)

        def body(carry, batch):
            return alg.client_step(ctx, carry, batch)

        init = (params_global, self.opt.init(params_global), state)
        (w_new, _, state_f), losses = jax.lax.scan(body, init, stacked)
        if not alg.stateful:
            return w_new, losses
        w_new, new_state, msg = alg.client_finalize(
            w_new, params_global, state_f, jnp.int32(_batch_len(stacked)),
            server_ctx, self.fed)
        return w_new, new_state, msg, losses

    def _run_padded(self, params_global, stacked, n_iters, mask,
                    server_ctx=(), state=()):
        """Masked scan over an H_max-padded stack: steps with index >=
        ``n_iters`` (a traced int32 scalar) are identity on the carry and
        emit NaN. H^k therefore never enters the compile key — one program
        covers every iteration budget at this pad length."""
        alg = self.algorithm
        ctx = self._ctx(params_global, mask, server_ctx)

        def body(carry, xs):
            i, batch = xs
            new_carry, loss = alg.client_step(ctx, carry, batch)
            active = i < n_iters
            carry = jax.tree_util.tree_map(
                lambda new, old: jnp.where(active, new, old),
                new_carry, carry)
            return carry, jnp.where(active, loss, jnp.nan)

        H = _batch_len(stacked)
        init = (params_global, self.opt.init(params_global), state)
        (w_new, _, state_f), losses = jax.lax.scan(
            body, init, (jnp.arange(H, dtype=jnp.int32), stacked))
        if not alg.stateful:
            return w_new, losses
        w_new, new_state, msg = alg.client_finalize(
            w_new, params_global, state_f, n_iters, server_ctx, self.fed)
        return w_new, new_state, msg, losses

    def _run_padded_batch(self, params_global, stacked_clients, iters, mask,
                          server_ctx=(), states=()):
        return jax.vmap(
            lambda s, n, st: self._run_padded(params_global, s, n, mask,
                                              server_ctx, st)
        )(stacked_clients, iters, states)

    def _alg_inputs(self, params_global, server_ctx, state_or_states,
                    ids=None):
        """Resolve the (server_ctx, state) pair for a call: empty pytrees
        for stateless algorithms (zero traced leaves — the legacy
        programs), the bound instance's persisted state otherwise."""
        alg = self.algorithm
        if not alg.stateful:
            return (), ()
        if server_ctx is None:
            server_ctx = alg.ctx_for(params_global)
        if state_or_states is None:
            if ids is None:
                state_or_states = alg.state_for(0, params_global)
            else:
                state_or_states = alg.stacked_states(params_global, ids)
        return server_ctx, state_or_states

    @property
    def num_compiled(self) -> int:
        """Distinct programs actually traced across this engine's entry
        points. For the unpadded path H is the scan length (a static
        shape): one compile per distinct H. For the padded path H^k is a
        traced argument: one compile per (n_clients, H_max) round shape
        regardless of the H vector."""
        return self._jits.num_compiled

    def __call__(self, params_global, stacked, mask=None, donate=False,
                 server_ctx=None, state=None):
        """``donate=True`` hands ``stacked``'s buffers to XLA — only safe
        when the caller will not touch them again (fresh stack per call).

        Stateful algorithms return ``(w_new, new_state, msg, losses)``
        instead of ``(w_new, losses)``; ``server_ctx``/``state`` default
        to the bound algorithm instance's persisted values (client 0)."""
        if mask is None:
            mask = trainable_mask(params_global, self.fed.trainable)
        server_ctx, state = self._alg_inputs(params_global, server_ctx,
                                             state)
        return self._jits.call("run", self._run, (1,) if donate else (),
                               (params_global, stacked, mask, server_ctx,
                                state))

    def run_batch(self, params_global, client_stacks, iters=None, mask=None,
                  donate=None, server_ctx=None, states=None,
                  client_ids=None):
        """Batched padded execution of many clients with per-client H^k.

        ``client_stacks``: a sequence of per-client stacked batch pytrees
        (padded here via ``pad_client_batches``; the pad copy is engine-
        owned, so it is donated) or an already client-stacked pytree with
        (n_clients, H_max, ...) leaves plus an explicit ``iters``. Returns
        ``(w_news, losses)`` with leading client axes; ``losses`` rows are
        NaN beyond each client's H^k. Stateful algorithms additionally
        take per-client ``states`` stacked on the client axis (default:
        the bound instance's states for ``client_ids``, default
        ``range(n)``) and return ``(w_news, new_states, msgs, losses)``.
        """
        if isinstance(client_stacks, (list, tuple)):
            client_stacks, lens = pad_client_batches(
                client_stacks, H_max=_pad_H(self.fed, client_stacks))
            if iters is None:
                iters = lens
            if donate is None:
                donate = True
        if iters is None:
            iters = _full_iters(client_stacks)
        if mask is None:
            mask = trainable_mask(params_global, self.fed.trainable)
        server_ctx, states = self._alg_inputs(
            params_global, server_ctx, states,
            ids=(client_ids if client_ids is not None
                 else range(_batch_len(client_stacks))))
        return self._jits.call(
            "batch", self._run_padded_batch, (1,) if donate else (),
            (params_global, client_stacks, jnp.asarray(iters, jnp.int32),
             mask, server_ctx, states))

    def unstack(self, stacked, n: int):
        """Split a client-stacked pytree (leaves (n, ...)) into n
        per-client pytrees in ONE jitted dispatch.

        The eager equivalent — ``tree_map(lambda a: a[j], stacked)`` per
        client — enqueues n × n_leaves tiny slice ops; for a steady-state
        async burst that fan-out is paid per *group* and would eat the
        window's dispatch savings. Living on the engine's ``_JitCache``,
        the compiled slice programs share the engine's lifetime (and the
        FIFO-bounded engine cache) instead of accumulating at module
        scope; one compile per burst size n.
        """
        def _unstack(tree):
            return tuple(jax.tree_util.tree_map(lambda a: a[j], tree)
                         for j in range(n))

        return self._jits.call(("unstack", n), _unstack, (), (stacked,))


_ENGINE_CACHE: dict = {}
_ENGINE_CACHE_MAX = 32      # FIFO-bounded: engines hold compiled executables


def _engine_key(kind, cfg: ModelConfig, fed: FedConfig, loss_kwargs,
                algorithm=None):
    """Cache key over the fields that affect the compiled client program.

    Server-side knobs (mixing_beta, staleness_a, ...) don't — two sweeps
    differing only in staleness must share compiled engines. ``kind`` may
    carry extra identity (e.g. the sharded round's Mesh). The algorithm
    enters through ``cache_key()`` — equal keys promise equal traced
    hooks, so all default/FedProx callers share one engine, and all
    Scaffold instances share another (their mutable per-client state
    lives on the caller's instance and flows through arguments).
    """
    lk = tuple(sorted((loss_kwargs or {}).items()))
    ak = (algorithm.cache_key() if algorithm is not None
          else algorithms.FedProx().cache_key())
    key = (kind, cfg, fed.lr, fed.momentum, fed.weight_decay,
           fed.prox_theta, fed.trainable, lk, ak)
    try:
        hash(key)
    except TypeError:
        return None
    return key


def cached_engine(key, build):
    """FIFO-bounded engine memo shared across subsystems.

    Engines hold compiled executables, so repeated construction (sweeps,
    benchmarks, the KD->fine-tune pipeline) must reuse them. The fed
    engines key through ``_engine_key``; the distillation engines
    (``core.distill``) bring their own hashable keys. ``key=None`` (or an
    unhashable key) skips memoization and builds fresh.
    """
    if key is not None:
        try:
            hash(key)
        except TypeError:
            key = None
    if key is None:
        return build()
    if key not in _ENGINE_CACHE:
        while len(_ENGINE_CACHE) >= _ENGINE_CACHE_MAX:
            _ENGINE_CACHE.pop(next(iter(_ENGINE_CACHE)))
        _ENGINE_CACHE[key] = build()
    return _ENGINE_CACHE[key]


def _cached_engine(kind, cfg, fed, loss_kwargs, build, algorithm=None):
    return cached_engine(
        _engine_key(kind, cfg, fed, loss_kwargs, algorithm), build)


def make_client_run(cfg: ModelConfig, fed: FedConfig,
                    loss_kwargs=None, algorithm=None) -> ClientRun:
    """The scan engine replacing per-iteration ``step(...)`` dispatch.

    Memoized on the client-relevant config fields (+ the algorithm's
    ``cache_key``) so repeated simulator runs (hyperparameter sweeps,
    benchmarks) reuse compiled programs. Stateful callers should pass
    ``server_ctx``/``states`` explicitly — the memoized engine may be
    bound to a different (behaviorally identical) algorithm instance.
    """
    return _cached_engine(
        "client", cfg, fed, loss_kwargs,
        lambda: ClientRun(cfg, fed, loss_kwargs, algorithm=algorithm),
        algorithm=algorithm)


def _weighted_params(w_news, weights, params_global):
    """einsum over the client axis, accumulated in f32, cast back."""
    return jax.tree_util.tree_map(
        lambda l, p: jnp.einsum(
            "c,c...->...", weights,
            l.astype(jnp.float32)).astype(p.dtype),
        w_news, params_global)


class SyncRound:
    """vmap-over-clients FedAvg round: one batched program per round.

    ``round(params_global, client_stacks, weights, mask=None, iters=None)``
    -> ``(new_global, losses (n_clients, H))``. ``client_stacks`` is either
    a sequence of per-client stacked batch pytrees (stacked — or, when
    their H^k differ, padded — here) or an already client-stacked pytree
    with leading (n_clients, H) axes. With ``iters`` the padded masked-scan
    program runs: per-client H^k as a traced vector, one compile per round
    shape, NaN losses past each client's budget.

    ``donate_params=True`` additionally donates the old global params —
    the new global aliases their buffers exactly — and must only be set
    by callers that will never touch the passed-in params again.
    """

    def __init__(self, cfg: ModelConfig, fed: FedConfig, loss_kwargs=None,
                 algorithm=None):
        # share the memoized ClientRun (it is stateless): async dispatches
        # and the sync round's inner scan then reuse one trace cache
        self.client = make_client_run(cfg, fed, loss_kwargs,
                                      algorithm=algorithm)
        self.algorithm = self.client.algorithm
        self.fed = fed
        self._jits = _JitCache()

    def _reduce(self, out, params_global, weights, server_ctx):
        """The round's server half: algorithm prepare → weighted fold →
        algorithm finish. Stateless algorithms keep the legacy
        ``(new_global, losses)`` output exactly."""
        alg = self.algorithm
        if not alg.stateful:
            w_news, losses = out
            return _weighted_params(w_news, weights, params_global), losses
        w_news, new_states, msgs, losses = out
        w_eff = alg.reduce_prepare(w_news, params_global, new_states,
                                   server_ctx)
        avg = _weighted_params(w_eff, weights, params_global)
        msg_sum = algorithms.weighted_state_sum(msgs, weights)
        new_global, new_ctx = alg.reduce_finish(avg, msg_sum, server_ctx,
                                                params_global)
        return new_global, new_ctx, new_states, losses

    def _rnd(self, params_global, stacked_clients, weights, mask,
             server_ctx=(), states=()):
        # anchor (and mask) broadcast; batch stacks are per-client
        out = jax.vmap(
            lambda s, st: self.client._run(params_global, s, mask,
                                           server_ctx, st)
        )(stacked_clients, states)
        return self._reduce(out, params_global, weights, server_ctx)

    def _rnd_padded(self, params_global, stacked_clients, weights, iters,
                    mask, server_ctx=(), states=()):
        out = self.client._run_padded_batch(
            params_global, stacked_clients, iters, mask, server_ctx,
            states)
        return self._reduce(out, params_global, weights, server_ctx)

    @property
    def num_compiled(self) -> int:
        """Distinct traced programs — one per (n_clients, H) round shape
        (the padded path's H^k vector is traced, not a compile key)."""
        return self._jits.num_compiled

    def _prep(self, params_global, client_stacks, weights, mask, iters,
              donate):
        if isinstance(client_stacks, (list, tuple)):
            try:
                client_stacks = stack_client_batches(client_stacks)
            except ValueError:
                client_stacks, lens = pad_client_batches(
                    client_stacks, H_max=_pad_H(self.fed, client_stacks))
                if iters is None:   # caller-supplied H^k wins over lens
                    iters = lens
            if donate is None:
                donate = True    # the stack was built here; caller never
        n = _batch_len(client_stacks)    # sees it, so XLA may reuse it
        if weights is None:
            weights = jnp.full((n,), 1.0 / n, jnp.float32)
        else:
            weights = jnp.asarray(weights, jnp.float32)
        if mask is None:
            mask = trainable_mask(params_global, self.fed.trainable)
        return client_stacks, weights, mask, iters, bool(donate), n

    @staticmethod
    def _donated(donate, donate_params):
        return ((0,) if donate_params else ()) + ((1,) if donate else ())

    def __call__(self, params_global, client_stacks, weights=None,
                 mask=None, iters=None, donate=None,
                 donate_params: bool = False, server_ctx=None, states=None,
                 client_ids=None):
        client_stacks, weights, mask, iters, donate, n = self._prep(
            params_global, client_stacks, weights, mask, iters, donate)
        server_ctx, states = self.client._alg_inputs(
            params_global, server_ctx, states,
            ids=(client_ids if client_ids is not None else range(n)))
        argnums = self._donated(donate, donate_params)
        if iters is None:
            return self._jits.call(
                "rnd", self._rnd, argnums,
                (params_global, client_stacks, weights, mask, server_ctx,
                 states))
        return self._jits.call(
            "pad", self._rnd_padded, argnums,
            (params_global, client_stacks, weights,
             jnp.asarray(iters, jnp.int32), mask, server_ctx, states))


def make_sync_round(cfg: ModelConfig, fed: FedConfig,
                    loss_kwargs=None, algorithm=None) -> SyncRound:
    """The vmap engine replacing fedavg's per-client Python loop.

    Memoized like ``make_client_run``.
    """
    return _cached_engine(
        "sync", cfg, fed, loss_kwargs,
        lambda: SyncRound(cfg, fed, loss_kwargs, algorithm=algorithm),
        algorithm=algorithm)


class ShardedSyncRound(SyncRound):
    """Padded sync round sharded over a device mesh with ``shard_map``.

    The client axis splits across the mesh's client axis (or axes —
    ``launch.mesh.make_fleet_mesh``; specs from
    ``sharding.specs.fed_round_specs``): each shard scans its local
    clients under ``vmap``, reduces its weight-scaled parameter sum, and
    the global weighted average forms with ``psum``. Params and mask
    replicate; batch stacks, weights, and the H^k vector shard on the
    leading client axis. When n_clients does not divide the axis size the
    round pads with zero-weight, zero-iteration dummy clients and slices
    their losses back off.

    On a two-level ``('edge', 'clients')`` mesh the reduction is the
    *hierarchical edge-aggregator tree*: each shard's weight-scaled
    partial first psums over ``'clients'`` (clients → their edge
    aggregator), then the edge partials psum over ``'edge'`` (edge
    aggregators → server). Since every weight-scaled client model is
    added exactly once either way, the nested reduction equals the flat
    psum weighted average — Σ_e Σ_{k∈e} w_k·θ_k = Σ_k w_k·θ_k — which
    the fleet property tests assert (bit-identical on a single-shard
    mesh, float32-close under real sharding where reduction order is
    XLA's choice).
    """

    def __init__(self, cfg: ModelConfig, fed: FedConfig, mesh,
                 loss_kwargs=None, algorithm=None):
        from repro.sharding import specs as sh
        super().__init__(cfg, fed, loss_kwargs, algorithm=algorithm)
        self.mesh = mesh
        self._specs = sh.fed_round_specs(mesh)
        axis = self._specs["axis"]
        # hierarchy levels, innermost (leaf) first: a 1-D mesh reduces in
        # one psum; ('edge', 'clients') reduces clients-within-edge, then
        # across edges
        levels = tuple(reversed(axis)) if isinstance(axis, tuple) \
            else (axis,)

        def _psum_levels(tree):
            if not jax.tree_util.tree_leaves(tree):
                return tree
            for level in levels:     # nested: leaf aggregators upward
                tree = jax.lax.psum(tree, level)
            return tree

        def shard_fn(params_global, stacked_shard, w_shard, it_shard, mask,
                     server_ctx, states_shard):
            alg = self.algorithm
            out = self.client._run_padded_batch(
                params_global, stacked_shard, it_shard, mask, server_ctx,
                states_shard)
            if not alg.stateful:
                w_news, losses = out
                partial = jax.tree_util.tree_map(
                    lambda l: jnp.einsum("c,c...->...", w_shard,
                                         l.astype(jnp.float32)), w_news)
                partial = _psum_levels(partial)
                new = jax.tree_util.tree_map(
                    lambda t, p: t.astype(p.dtype), partial, params_global)
                return new, losses
            w_news, new_states, msgs, losses = out
            # per-client prepare (low-rank reconstruction, ...) is
            # elementwise on the client axis, so shard-local prepare +
            # the nested psum equals the global prepare + flat fold
            w_eff = alg.reduce_prepare(w_news, params_global, new_states,
                                       server_ctx)
            partial = jax.tree_util.tree_map(
                lambda l: jnp.einsum("c,c...->...", w_shard,
                                     l.astype(jnp.float32)), w_eff)
            partial = _psum_levels(partial)
            msg_sum = _psum_levels(
                algorithms.weighted_state_sum(msgs, w_shard))
            avg = jax.tree_util.tree_map(
                lambda t, p: t.astype(p.dtype), partial, params_global)
            new_global, new_ctx = alg.reduce_finish(
                avg, msg_sum, server_ctx, params_global)
            return new_global, new_ctx, new_states, losses

        c, r = self._specs["clients"], self._specs["replicated"]
        out_specs = (r, r, c, c) if self.algorithm.stateful else (r, c)
        self._sharded_rnd = sh.shard_map(
            shard_fn, mesh=mesh, in_specs=(r, c, c, c, r, r, c),
            out_specs=out_specs)

    def _n_shards(self) -> int:
        axis = self._specs["axis"]
        if isinstance(axis, tuple):
            n = 1
            for a in axis:
                n *= self.mesh.shape[a]
            return n
        return self.mesh.shape[axis]

    def __call__(self, params_global, client_stacks, weights=None,
                 mask=None, iters=None, donate=None,
                 donate_params: bool = False, server_ctx=None, states=None,
                 client_ids=None):
        client_stacks, weights, mask, iters, donate, n = self._prep(
            params_global, client_stacks, weights, mask, iters, donate)
        if iters is None:        # homogeneous: every client runs full H
            iters = _full_iters(client_stacks)
        iters = np.asarray(iters, np.int32)
        ids = client_ids if client_ids is not None else range(n)
        server_ctx, states = self.client._alg_inputs(
            params_global, server_ctx, states, ids=ids)
        n_shards = self._n_shards()
        pad = (-n) % n_shards
        if pad:                  # zero-weight dummies round the axis up
            client_stacks = jax.tree_util.tree_map(
                lambda l: np.concatenate(
                    [np.asarray(l)] + [np.asarray(l[:1])] * pad),
                client_stacks)
            weights = jnp.concatenate(
                [weights, jnp.zeros((pad,), jnp.float32)])
            iters = np.concatenate([iters, np.zeros((pad,), np.int32)])
            states = jax.tree_util.tree_map(
                lambda l: jnp.concatenate([l] + [l[:1]] * pad), states)
        out = self._jits.call(
            "shard", self._sharded_rnd,
            self._donated(donate, donate_params),
            (params_global, client_stacks, weights,
             jnp.asarray(iters, jnp.int32), mask, server_ctx, states))
        if not self.algorithm.stateful:
            new, losses = out
            return new, losses[:n]
        new, new_ctx, new_states, losses = out
        new_states = jax.tree_util.tree_map(lambda l: l[:n], new_states)
        return new, new_ctx, new_states, losses[:n]


def make_sharded_sync_round(cfg: ModelConfig, fed: FedConfig, mesh=None,
                            loss_kwargs=None,
                            algorithm=None) -> ShardedSyncRound:
    """Sync-round engine whose client axis is split over ``mesh`` (default:
    this host's whole device set as a 1-D ``('clients',)`` mesh).

    Memoized like ``make_sync_round`` with the mesh folded into the key.
    """
    if mesh is None:
        from repro.launch.mesh import make_fleet_mesh
        mesh = make_fleet_mesh()
    return _cached_engine(
        ("shard", mesh), cfg, fed, loss_kwargs,
        lambda: ShardedSyncRound(cfg, fed, mesh, loss_kwargs,
                                 algorithm=algorithm),
        algorithm=algorithm)


def make_hierarchical_sync_round(cfg: ModelConfig, fed: FedConfig,
                                 mesh=None, edges: int | None = None,
                                 loss_kwargs=None,
                                 algorithm=None) -> ShardedSyncRound:
    """Sync-round engine over a two-level ``('edge', 'clients')`` mesh:
    the hierarchical edge-aggregator tree (clients → edge aggregators →
    server as nested psums — provably the flat weighted average; see
    ``ShardedSyncRound``).

    Default mesh: this host's devices factored into
    ``launch.mesh.make_fleet_mesh(edges=...)`` (a 1-device host runs the
    identical program on a degenerate (1, 1) tree). Memoized like
    ``make_sharded_sync_round`` with the mesh folded into the key.
    """
    if mesh is None:
        from repro.launch.mesh import make_fleet_mesh
        mesh = make_fleet_mesh(edges=edges if edges is not None else 0)
    if not {"edge", "clients"} <= set(mesh.axis_names):
        raise ValueError(
            f"hierarchical round needs a ('edge', 'clients') mesh, got "
            f"axes {mesh.axis_names}")
    return _cached_engine(
        ("hier", mesh), cfg, fed, loss_kwargs,
        lambda: ShardedSyncRound(cfg, fed, mesh, loss_kwargs,
                                 algorithm=algorithm),
        algorithm=algorithm)
