"""Synchronous FedAvg baseline (McMahan et al. [30]; paper baseline #2).

Each round every client runs E_local epochs from the current global model;
the server replaces the model with the data-size-weighted average. Wall
clock per round = slowest client (the straggler penalty the async variant
removes).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.fedasync import make_client_step
from repro.optim import trainable_mask
from repro.types import FedConfig, ModelConfig


@jax.jit
def weighted_average(param_trees: Sequence, weights: jax.Array):
    """weights normalized data sizes, shape (n_clients,)."""
    def avg(*leaves):
        stacked = jnp.stack([l.astype(jnp.float32) for l in leaves])
        w = weights.reshape((-1,) + (1,) * (stacked.ndim - 1))
        return jnp.sum(stacked * w, axis=0).astype(leaves[0].dtype)
    return jax.tree_util.tree_map(avg, *param_trees)


def fedavg_round(params_global, client_batches: Sequence, cfg: ModelConfig,
                 fed: FedConfig, step=None, opt=None, mask=None,
                 data_sizes: Sequence[int] | None = None):
    """One synchronous round. client_batches: per-client iterable of batches.

    Returns (new_global_params, per_client_losses).
    """
    if step is None:
        step, opt = make_client_step(cfg, fed)
    if mask is None:
        mask = trainable_mask(params_global, fed.trainable)
    results, losses = [], []
    for batches in client_batches:
        params = params_global
        opt_state = opt.init(params)
        cl = []
        for i, batch in zip(range(fed.local_iters_max), batches):
            params, opt_state, loss = step(params, opt_state, params_global,
                                           batch, mask)
            cl.append(float(loss))
        results.append(params)
        losses.append(cl)
    n = len(results)
    if data_sizes is None:
        w = jnp.full((n,), 1.0 / n, jnp.float32)
    else:
        s = jnp.asarray(data_sizes, jnp.float32)
        w = s / jnp.sum(s)
    return weighted_average(results, w), losses
