"""Synchronous FedAvg baseline (McMahan et al. [30]; paper baseline #2).

Each round every client runs E_local epochs from the current global model;
the server replaces the model with the data-size-weighted average. Wall
clock per round = slowest client (the straggler penalty the async variant
removes).

``fedavg_round`` runs the whole round as ONE batched program: client batch
stacks get a leading client axis and ``jax.vmap`` maps the scan-compiled
local training over it (see core/fed_engine.py), so a sync round costs a
single dispatch instead of n_clients × H jitted steps plus n_clients × H
host syncs. Heterogeneous fleets — clients with different iteration
budgets H^k, including clients that ran out of data — batch too: their
stacks zero-pad to a common H_max and the engine's per-client iteration
mask makes padded steps identity (docs/fed_engine.md). Only clients whose
*batch shapes* disagree drop to the per-client fallback.
``fedavg_round_loop`` is the legacy per-client Python loop, kept as the
parity oracle.
"""
from __future__ import annotations

import itertools
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import algorithms, compile_cache, fed_engine
from repro.core.fedasync import cached_client_step, make_client_step
from repro.data.synthetic import stack_batches
from repro.optim import trainable_mask
from repro.types import FedConfig, ModelConfig


# Aggregation shares one counted jit pool: one traced program per client
# count (the pytree arity is the compile key), observable via num_compiled.
_JITS = compile_cache.JitCache()


def _weighted_average_impl(param_trees, weights):
    def avg(*leaves):
        stacked = jnp.stack([l.astype(jnp.float32) for l in leaves])
        w = weights.reshape((-1,) + (1,) * (stacked.ndim - 1))
        return jnp.sum(stacked * w, axis=0).astype(leaves[0].dtype)
    return jax.tree_util.tree_map(avg, *param_trees)


def weighted_average(param_trees: Sequence, weights: jax.Array):
    """weights normalized data sizes, shape (n_clients,)."""
    return _JITS.call("weighted_average", _weighted_average_impl,
                      (), (list(param_trees), weights))


def _client_weights(n: int, data_sizes: Sequence[int] | None):
    if data_sizes is None:
        return jnp.full((n,), 1.0 / n, jnp.float32)
    s = jnp.asarray(data_sizes, jnp.float32)
    return s / jnp.sum(s)


def _alg_round_io(algorithm, params_global, n, client_ids):
    """Explicit per-round state for a stateful algorithm: the memoized
    engine may be bound to a different (equal-keyed) instance, so the
    *caller's* instance supplies ctx/states and commits the results.
    Returns (ids, engine-call kwargs); ids is None for stateless."""
    if algorithm is None or not algorithm.stateful:
        return None, {}
    ids = list(client_ids) if client_ids is not None else list(range(n))
    return ids, {"server_ctx": algorithm.ctx_for(params_global),
                 "states": algorithm.stacked_states(params_global, ids)}


def _alg_round_commit(algorithm, ids, out):
    """Unpack an engine round output, committing stateful results back to
    the caller's algorithm instance. Returns (new_global, losses)."""
    if ids is None:
        return out
    new_global, new_ctx, new_states, losses = out
    algorithm.set_ctx(new_ctx)
    algorithm.store_states(ids, new_states)
    return new_global, losses


def fedavg_round(params_global, client_batches: Sequence, cfg: ModelConfig,
                 fed: FedConfig, engine=None,
                 mask=None, data_sizes: Sequence[int] | None = None,
                 donate_params: bool = False, algorithm=None,
                 client_ids: Sequence[int] | None = None):
    """One synchronous round as a single vmap-batched program.

    ``client_batches``: per-client iterable of batches (the legacy
    contract); each is stacked to at most H = fed.local_iters_max
    iterations and all clients run together. Returns
    (new_global_params, per_client_losses) with losses as lists of floats
    (length H^k per client), matching the loop oracle. A homogeneous fleet
    takes the plain vmap path; clients with *different batch counts* H^k
    (including zero — out of data) pad to H_max and run the masked-scan
    path. Only batch shapes that disagree within or across clients drop to
    the per-client fallback; see ``_ragged_fallback``.

    ``engine``: a ``fed_engine.SyncRound`` instance, ``None`` (the default
    memoized vmap engine), or an ``core.fleet.EngineSpec`` / its string
    value — the one validated definition of the engine knob ("loop"
    routes to ``fedavg_round_loop``).

    ``donate_params=True`` lets the engine alias the new global onto
    ``params_global``'s buffers — only pass it when the caller will never
    use ``params_global`` again (e.g. round r > 0 of a training loop).

    ``algorithm``: a ``core.algorithms.FedAlgorithm`` (or ``None`` for the
    default ``FedProx``, bit-identical to the pre-refactor round).
    Stateful algorithms persist per-client state on the instance keyed by
    ``client_ids`` (default ``range(n_clients)``).
    """
    if algorithm is not None:
        algorithm = algorithms.make_algorithm(algorithm)
    if engine is not None and not isinstance(engine, fed_engine.SyncRound):
        from repro.core.fleet import EngineSpec
        spec = EngineSpec.from_str(engine)
        engine = spec.build_sync(cfg, fed, algorithm=algorithm)
        if engine is None:                  # EngineSpec.LOOP
            return fedavg_round_loop(params_global, client_batches, cfg,
                                     fed, mask=mask, data_sizes=data_sizes,
                                     algorithm=algorithm,
                                     client_ids=client_ids)
    # materialize up to H batches per client first: iterators may be
    # generators, so raggedness must be detected before anything is lost
    client_lists = [list(itertools.islice(b, fed.local_iters_max))
                    for b in client_batches]
    # one signature scan decides all three paths: a single shared batch
    # signature is the batched programs' precondition; equal non-zero
    # counts additionally allow the mask-free homogeneous program
    sigs = {_batch_sig(b) for bl in client_lists for b in bl}
    counts = [len(bl) for bl in client_lists]
    if client_lists and len(sigs) == 1:
        if min(counts) == max(counts) > 0:
            # stack straight to (n_clients, H, ...) — one host copy, not
            # a per-client stack followed by a cross-client restack
            keys = list(client_lists[0][0])
            stacked_clients = {
                k: np.stack([[b[k] for b in bl] for bl in client_lists])
                for k in keys}
            if engine is None:
                engine = fed_engine.make_sync_round(cfg, fed,
                                                    algorithm=algorithm)
            weights = _client_weights(len(client_lists), data_sizes)
            ids, alg_kw = _alg_round_io(algorithm, params_global,
                                        len(client_lists), client_ids)
            out = engine(params_global, stacked_clients,
                         weights=weights, mask=mask, donate=True,
                         donate_params=donate_params, **alg_kw)
            new_global, losses = _alg_round_commit(algorithm, ids, out)
            return new_global, [[float(x) for x in row]
                                for row in np.asarray(losses)]
        return _padded_round(params_global, client_lists, cfg, fed,
                             engine, mask, data_sizes, donate_params,
                             algorithm, client_ids)
    return _ragged_fallback(params_global, client_lists, cfg, fed,
                            engine, mask, data_sizes, algorithm, client_ids)


def _batch_sig(b):
    return tuple(sorted((k, np.shape(v), str(np.asarray(v).dtype))
                        for k, v in b.items()))


def _padded_round(params_global, client_lists, cfg, fed, engine, mask,
                  data_sizes, donate_params=False, algorithm=None,
                  client_ids=None):
    """Heterogeneous-H round as one padded masked-scan program.

    Batches write straight into one zero-initialized (n_clients, H_max,
    ...) array per key — a single host copy, mirroring the homogeneous
    branch — and the engine threads the true H^k vector through the scan
    mask: one compiled program per round shape, whatever the H^k draw.
    Empty clients run zero iterations and contribute the unchanged global
    to the weighted average, matching the loop oracle. Zero pad rows are
    what the mask discards, so their contents never matter.
    """
    ref = next(b for bl in client_lists for b in bl)
    n = len(client_lists)
    H_max = max(fed.local_iters_max, max(len(bl) for bl in client_lists))
    iters = np.asarray([len(bl) for bl in client_lists], np.int32)
    stacked = {}
    for k, v in ref.items():
        out = np.zeros((n, H_max) + np.shape(v), np.asarray(v).dtype)
        for c, bl in enumerate(client_lists):
            for i, b in enumerate(bl):
                out[c, i] = b[k]
        stacked[k] = out
    if engine is None:
        engine = fed_engine.make_sync_round(cfg, fed, algorithm=algorithm)
    weights = _client_weights(n, data_sizes)
    ids, alg_kw = _alg_round_io(algorithm, params_global, n, client_ids)
    out = engine(params_global, stacked, weights=weights,
                 mask=mask, iters=iters, donate=True,
                 donate_params=donate_params, **alg_kw)
    new_global, losses = _alg_round_commit(algorithm, ids, out)
    losses = np.asarray(losses)
    return new_global, [[float(x) for x in row[:h]]
                        for row, h in zip(losses, iters)]


def _ragged_fallback(params_global, client_lists, cfg, fed, engine,
                     mask, data_sizes, algorithm=None, client_ids=None):
    """Per-client runs + weighted average when no batched program can form
    (batch *shapes* disagree — count-only raggedness takes
    ``_padded_round``): stackable clients use the scan engine,
    within-client-ragged ones drop to the per-iteration step loop, empty
    ones return the global model. Stateful algorithms route through the
    algorithm-aware loop oracle + ``server_reduce``."""
    if algorithm is not None and algorithm.stateful:
        ids = list(client_ids) if client_ids is not None \
            else list(range(len(client_lists)))
        if mask is None:
            mask = trainable_mask(params_global, fed.trainable)
        ctx = algorithm.ctx_for(params_global)
        w_news, states, msgs, losses = [], [], [], []
        for k, bl in zip(ids, client_lists):
            w, st, msg, ls = algorithms.client_update_loop(
                params_global, bl, cfg, fed, algorithm, client_id=k,
                mask=mask, server_ctx=ctx)
            w_news.append(w)
            states.append(st)
            msgs.append(msg)
            losses.append(ls)
        new_global, _ = algorithms.server_reduce(
            algorithm, params_global, w_news, states, msgs,
            _client_weights(len(ids), data_sizes), server_ctx=ctx)
        return new_global, losses
    # reuse the round engine's client (and its compile cache) if provided —
    # a fresh ClientRun per round would recompile every call
    run = engine.client if engine is not None \
        else fed_engine.make_client_run(cfg, fed)
    if mask is None:
        mask = trainable_mask(params_global, fed.trainable)
    results, losses = [], []
    for bl in client_lists:
        if not bl:                          # client out of data
            results.append(params_global)
            losses.append([])
            continue
        try:
            s = stack_batches(bl)
        except ValueError:                  # ragged shapes within client:
            s = None                        # per-iteration oracle path
        if s is None:
            step, opt = cached_client_step(cfg, fed)
            params = params_global
            opt_state = opt.init(params)
            cl = []
            for batch in bl:
                params, opt_state, loss = step(params, opt_state,
                                               params_global, batch, mask)
                cl.append(float(loss))
            results.append(params)
            losses.append(cl)
        else:
            w_new, ls = run(params_global, s, mask=mask)
            results.append(w_new)
            losses.append([float(x) for x in np.asarray(ls)])
    return (weighted_average(results,
                             _client_weights(len(results), data_sizes)),
            losses)


def fedavg_round_loop(params_global, client_batches: Sequence,
                      cfg: ModelConfig, fed: FedConfig, step=None, opt=None,
                      mask=None, data_sizes: Sequence[int] | None = None,
                      algorithm=None,
                      client_ids: Sequence[int] | None = None):
    """Legacy per-client / per-iteration loop — the engine's parity oracle.

    One jitted step dispatch and one ``float(loss)`` host sync per local
    iteration. Returns (new_global_params, per_client_losses).
    Stateful algorithms route through the algorithm-aware loop oracle
    (``algorithms.client_update_loop`` + ``server_reduce``); stateless
    ones keep the legacy step, bit-identical to the pre-refactor loop.
    """
    if algorithm is not None:
        algorithm = algorithms.make_algorithm(algorithm)
        if algorithm.stateful:
            client_lists = [list(itertools.islice(b, fed.local_iters_max))
                            for b in client_batches]
            return _ragged_fallback(params_global, client_lists, cfg, fed,
                                    None, mask, data_sizes, algorithm,
                                    client_ids)
    if step is None:
        step, opt = make_client_step(cfg, fed)
    if mask is None:
        mask = trainable_mask(params_global, fed.trainable)
    results, losses = [], []
    for batches in client_batches:
        params = params_global
        opt_state = opt.init(params)
        cl = []
        for i, batch in zip(range(fed.local_iters_max), batches):
            params, opt_state, loss = step(params, opt_state, params_global,
                                           batch, mask)
            cl.append(float(loss))
        results.append(params)
        losses.append(cl)
    n = len(results)
    return (weighted_average(results, _client_weights(n, data_sizes)),
            losses)
