"""Synchronous FedAvg baseline (McMahan et al. [30]; paper baseline #2).

Each round every client runs E_local epochs from the current global model;
the server replaces the model with the data-size-weighted average. Wall
clock per round = slowest client (the straggler penalty the async variant
removes).

``fedavg_round`` runs the whole round as ONE batched program: client batch
stacks get a leading client axis and ``jax.vmap`` maps the scan-compiled
local training over it (see core/fed_engine.py), so a homogeneous sync
round costs a single dispatch instead of n_clients × H jitted steps plus
n_clients × H host syncs. ``fedavg_round_loop`` is the legacy per-client
Python loop, kept as the parity oracle.
"""
from __future__ import annotations

import itertools
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import fed_engine
from repro.core.fedasync import cached_client_step, make_client_step
from repro.data.synthetic import stack_batches
from repro.optim import trainable_mask
from repro.types import FedConfig, ModelConfig


@jax.jit
def weighted_average(param_trees: Sequence, weights: jax.Array):
    """weights normalized data sizes, shape (n_clients,)."""
    def avg(*leaves):
        stacked = jnp.stack([l.astype(jnp.float32) for l in leaves])
        w = weights.reshape((-1,) + (1,) * (stacked.ndim - 1))
        return jnp.sum(stacked * w, axis=0).astype(leaves[0].dtype)
    return jax.tree_util.tree_map(avg, *param_trees)


def _client_weights(n: int, data_sizes: Sequence[int] | None):
    if data_sizes is None:
        return jnp.full((n,), 1.0 / n, jnp.float32)
    s = jnp.asarray(data_sizes, jnp.float32)
    return s / jnp.sum(s)


def fedavg_round(params_global, client_batches: Sequence, cfg: ModelConfig,
                 fed: FedConfig, engine: fed_engine.SyncRound | None = None,
                 mask=None, data_sizes: Sequence[int] | None = None):
    """One synchronous round as a single vmap-batched program.

    ``client_batches``: per-client iterable of batches (the legacy
    contract); each is stacked to H = fed.local_iters_max iterations and
    all clients run together. Returns (new_global_params,
    per_client_losses) with losses as lists of floats, matching the loop
    oracle. The vmap program needs a homogeneous fleet — ragged clients
    (out of data, or batch shapes that don't stack) drop to a per-client
    fallback; see ``_ragged_fallback``.
    """
    # materialize up to H batches per client first: iterators may be
    # generators, so raggedness must be detected before anything is lost
    client_lists = [list(itertools.islice(b, fed.local_iters_max))
                    for b in client_batches]
    if client_lists and _is_homogeneous(client_lists):
        # stack straight to (n_clients, H, ...) — one host copy, not a
        # per-client stack followed by a cross-client restack
        keys = list(client_lists[0][0])
        stacked_clients = {
            k: np.stack([[b[k] for b in bl] for bl in client_lists])
            for k in keys}
        if engine is None:
            engine = fed_engine.make_sync_round(cfg, fed)
        weights = _client_weights(len(client_lists), data_sizes)
        new_global, losses = engine(params_global, stacked_clients,
                                    weights=weights, mask=mask)
        return new_global, [[float(x) for x in row]
                            for row in np.asarray(losses)]
    return _ragged_fallback(params_global, client_lists, cfg, fed,
                            engine, mask, data_sizes)


def _is_homogeneous(client_lists) -> bool:
    """True when every client has the same non-zero batch count and every
    batch shares keys/shapes/dtypes — the vmap program's precondition."""
    first = client_lists[0]
    if not first or any(len(bl) != len(first) for bl in client_lists):
        return False

    def sig(b):
        return tuple(sorted((k, np.shape(v), str(np.asarray(v).dtype))
                            for k, v in b.items()))

    ref = sig(first[0])
    return all(sig(b) == ref for bl in client_lists for b in bl)


def _ragged_fallback(params_global, client_lists, cfg, fed, engine,
                     mask, data_sizes):
    """Per-client runs + weighted average when the vmap program can't form:
    stackable clients use the scan engine, within-client-ragged ones drop
    to the per-iteration step loop, empty ones return the global model."""
    # reuse the round engine's client (and its compile cache) if provided —
    # a fresh ClientRun per round would recompile every call
    run = engine.client if engine is not None \
        else fed_engine.make_client_run(cfg, fed)
    if mask is None:
        mask = trainable_mask(params_global, fed.trainable)
    results, losses = [], []
    for bl in client_lists:
        if not bl:                          # client out of data
            results.append(params_global)
            losses.append([])
            continue
        try:
            s = stack_batches(bl)
        except ValueError:                  # ragged shapes within client:
            s = None                        # per-iteration oracle path
        if s is None:
            step, opt = cached_client_step(cfg, fed)
            params = params_global
            opt_state = opt.init(params)
            cl = []
            for batch in bl:
                params, opt_state, loss = step(params, opt_state,
                                               params_global, batch, mask)
                cl.append(float(loss))
            results.append(params)
            losses.append(cl)
        else:
            w_new, ls = run(params_global, s, mask=mask)
            results.append(w_new)
            losses.append([float(x) for x in np.asarray(ls)])
    return (weighted_average(results,
                             _client_weights(len(results), data_sizes)),
            losses)


def fedavg_round_loop(params_global, client_batches: Sequence,
                      cfg: ModelConfig, fed: FedConfig, step=None, opt=None,
                      mask=None, data_sizes: Sequence[int] | None = None):
    """Legacy per-client / per-iteration loop — the engine's parity oracle.

    One jitted step dispatch and one ``float(loss)`` host sync per local
    iteration. Returns (new_global_params, per_client_losses).
    """
    if step is None:
        step, opt = make_client_step(cfg, fed)
    if mask is None:
        mask = trainable_mask(params_global, fed.trainable)
    results, losses = [], []
    for batches in client_batches:
        params = params_global
        opt_state = opt.init(params)
        cl = []
        for i, batch in zip(range(fed.local_iters_max), batches):
            params, opt_state, loss = step(params, opt_state, params_global,
                                           batch, mask)
            cl.append(float(loss))
        results.append(params)
        losses.append(cl)
    n = len(results)
    return (weighted_average(results, _client_weights(n, data_sizes)),
            losses)
