"""Knowledge distillation with teaching assistants (paper §III-B, §V-A),
rebuilt as batched fleet workloads on the compiled-engine substrate.

L = α·L_cls + (1-α)·L_KD, with L_KD the (temperature-scaled) MSE between
teacher and student logits (the paper's choice at T=1 — *not* softened KL).
In TA stages the classification targets are the teacher's hard predictions
("the ground truth [is] the output of the teacher for the input x").

Three engines, all routing every jitted program through a shared
``compile_cache.JitCache`` (the PR-1/2 discipline; no stray ``jax.jit``):

``DistillEngine``
    One KD *epoch* — teacher forward + student forward/backward per step —
    as a single ``lax.scan`` program over a pre-stacked batch pytree. The
    fused Pallas KD kernel is the default loss (``kd_kernel="pallas"``),
    with the eager jnp implementation kept as a parity oracle behind
    ``kd_kernel="eager"`` (mirroring serving's ``decode_kernel=``).

``ScratchRun``
    The CE-only baseline/pretrain epoch (paper's "train from scratch").

``CodistillFleet``
    Codistillation across heterogeneous capacities (PAPERS.md: Knowledge
    Codistillation): m peers train on a shared probe stream, each
    distilling from the mean of its peers' round-start logits. Peers
    sharing a ModelConfig stack their params and run as ONE vmapped
    masked-scan program with per-member iteration budgets — the padded-scan
    engine pattern — so compile count scales with distinct architectures,
    not member count.

``run_chain`` executes the full teacher → TA* → student pipeline;
``launch/pipeline.py`` chains it into federated fine-tuning.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import fed_engine
from repro.core.compile_cache import JitCache as _JitCache
from repro.kernels import ops, ref
from repro.models import registry
from repro.optim import sgd
from repro.types import DistillConfig, ModelConfig

KD_KERNELS = ("pallas", "eager")


def kd_loss(student_logits, teacher_logits, labels, alpha: float,
            temperature: float = 1.0, kd_kernel: str = "pallas",
            valid=None):
    """Mean KD loss over all (valid) rows: α·CE + (1-α)·Σ((s-t)/T)².

    ``kd_kernel="pallas"`` (default) runs the fused single-pass kernel with
    its analytic backward; ``"eager"`` is the pure-jnp parity oracle.
    Leading axes flatten to rows (LM: B·S, resnet: B). ``valid`` masks rows
    out of both the sum and the denominator (the batched engines' padding).
    """
    if kd_kernel not in KD_KERNELS:
        raise ValueError(
            f"kd_kernel must be one of {KD_KERNELS}, got {kd_kernel!r}")
    R = 1
    for dim in student_logits.shape[:-1]:
        R *= dim
    V = student_logits.shape[-1]
    s = student_logits.reshape(R, V)
    t = teacher_logits.reshape(R, V)
    lab = labels.reshape(R)
    v = None if valid is None else valid.reshape(R)
    if kd_kernel == "pallas":
        per_row = ops.kd_loss_rows(s, t, lab, alpha,
                                   temperature=temperature, valid=v)
    else:
        per_row = ref.kd_loss_ref(s, t, lab, alpha,
                                  temperature=temperature, valid=v)
    if v is None:
        return jnp.mean(per_row)
    denom = jnp.maximum(jnp.sum(v.astype(jnp.float32)), 1.0)
    return jnp.sum(per_row) / denom


def clip_by_global_norm(grads, max_norm: float):
    sq = jax.tree_util.tree_map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads)
    gn = jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq, jnp.float32(0)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype),
                                  grads)


def _check_widths(a: ModelConfig, b: ModelConfig):
    if registry.logit_width(a) != registry.logit_width(b):
        raise ValueError(
            f"KD needs equal logit width: {a.name} vs {b.name}")


# ---------------------------------------------------------------------------
# Engines
# ---------------------------------------------------------------------------

class DistillEngine:
    """Scan-compiled KD: one epoch of teacher-fwd + student-step per call.

    ``epoch(teacher_params, params, opt_state, stacked)`` runs H KD steps
    as one program over a batch pytree with leading axis H (see
    ``repro.data.stack_batches``) and returns ``(params, opt_state,
    losses (H,))`` — the only host sync a caller pays is reading the loss
    vector. Teacher logits are recomputed inside the scan body (the
    paper's cost model: KD step = teacher fwd + student fwd/bwd), under
    ``stop_gradient``. ``step(...)`` is the single-step entry the epoch
    program must match (the per-step oracle, also the bench's dispatch-
    bound baseline). Gradients are clipped by global norm (the raw
    MSE-on-logits term is scale-unbounded).
    """

    def __init__(self, teacher_cfg: ModelConfig, student_cfg: ModelConfig,
                 dcfg: DistillConfig, kd_kernel: str = "pallas",
                 use_teacher_targets: bool = True, clip_norm: float = 1.0):
        if kd_kernel not in KD_KERNELS:
            raise ValueError(
                f"kd_kernel must be one of {KD_KERNELS}, got {kd_kernel!r}")
        _check_widths(teacher_cfg, student_cfg)
        self.teacher_cfg = teacher_cfg
        self.student_cfg = student_cfg
        self.dcfg = dcfg
        self.kd_kernel = kd_kernel
        self.use_teacher_targets = use_teacher_targets
        self.clip_norm = clip_norm
        self.opt = sgd(dcfg.lr, dcfg.momentum, dcfg.weight_decay)
        self._jits = _JitCache()

    # -- pure (unjitted) core --------------------------------------------
    def _loss(self, params, batch, teacher_logits):
        logits = registry.logits_fn(params, self.student_cfg, batch)
        labels = batch["labels"]
        if self.use_teacher_targets:
            labels = jnp.argmax(teacher_logits, axis=-1)
        return kd_loss(logits, teacher_logits, labels, self.dcfg.alpha,
                       temperature=self.dcfg.temperature,
                       kd_kernel=self.kd_kernel)

    def _step(self, teacher_params, params, opt_state, batch):
        t_logits = jax.lax.stop_gradient(
            registry.logits_fn(teacher_params, self.teacher_cfg, batch))
        loss, grads = jax.value_and_grad(self._loss)(params, batch, t_logits)
        if self.clip_norm:
            grads = clip_by_global_norm(grads, self.clip_norm)
        params, opt_state = self.opt.update(grads, opt_state, params)
        return params, opt_state, loss

    def _epoch(self, teacher_params, params, opt_state, stacked):
        def body(carry, batch):
            params, opt_state = carry
            params, opt_state, loss = self._step(
                teacher_params, params, opt_state, batch)
            return (params, opt_state), loss

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), stacked)
        return params, opt_state, losses

    @property
    def num_compiled(self) -> int:
        """Distinct traced programs — one per (H, batch-shape) epoch
        shape plus one per step shape if ``step`` was used."""
        return self._jits.num_compiled

    def epoch(self, teacher_params, params, opt_state, stacked,
              donate: bool = False):
        """``donate=True`` hands the batch stack's buffers to XLA — only
        safe when the caller built the stack for this call alone."""
        return self._jits.call(
            "epoch", self._epoch, (3,) if donate else (),
            (teacher_params, params, opt_state, stacked))

    def step(self, teacher_params, params, opt_state, batch):
        return self._jits.call(
            "step", self._step, (),
            (teacher_params, params, opt_state, batch))


class ScratchRun:
    """CE-only scan epoch: the paper's 'train from scratch' baseline and
    the server-side teacher pretrain. Same wire format as DistillEngine:
    ``epoch(params, opt_state, stacked)`` -> (params, opt_state, losses)."""

    def __init__(self, cfg: ModelConfig, dcfg: DistillConfig,
                 clip_norm: float = 1.0):
        self.cfg = cfg
        self.dcfg = dcfg
        self.clip_norm = clip_norm
        self.opt = sgd(dcfg.lr, dcfg.momentum, dcfg.weight_decay)
        self._jits = _JitCache()

    def _step(self, params, opt_state, batch):
        def loss_fn(p):
            return registry.loss_fn(p, self.cfg, batch, remat=False)[0]

        loss, grads = jax.value_and_grad(loss_fn)(params)
        if self.clip_norm:
            grads = clip_by_global_norm(grads, self.clip_norm)
        params, opt_state = self.opt.update(grads, opt_state, params)
        return params, opt_state, loss

    def _epoch(self, params, opt_state, stacked):
        def body(carry, batch):
            params, opt_state = carry
            params, opt_state, loss = self._step(params, opt_state, batch)
            return (params, opt_state), loss

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), stacked)
        return params, opt_state, losses

    @property
    def num_compiled(self) -> int:
        return self._jits.num_compiled

    def epoch(self, params, opt_state, stacked, donate: bool = False):
        return self._jits.call(
            "epoch", self._epoch, (2,) if donate else (),
            (params, opt_state, stacked))


def make_distill_engine(teacher_cfg: ModelConfig, student_cfg: ModelConfig,
                        dcfg: DistillConfig, kd_kernel: str = "pallas",
                        use_teacher_targets: bool = True,
                        clip_norm: float = 1.0) -> DistillEngine:
    """Memoized on the full program identity (both configs, the distill
    config, the kernel choice) through the fed engine's shared FIFO cache,
    so repeated pipeline runs reuse compiled epochs."""
    key = ("distill", teacher_cfg, student_cfg, dcfg, kd_kernel,
           use_teacher_targets, clip_norm)
    return fed_engine.cached_engine(
        key, lambda: DistillEngine(teacher_cfg, student_cfg, dcfg,
                                   kd_kernel=kd_kernel,
                                   use_teacher_targets=use_teacher_targets,
                                   clip_norm=clip_norm))


def make_scratch_run(cfg: ModelConfig, dcfg: DistillConfig,
                     clip_norm: float = 1.0) -> ScratchRun:
    key = ("scratch", cfg, dcfg, clip_norm)
    return fed_engine.cached_engine(
        key, lambda: ScratchRun(cfg, dcfg, clip_norm=clip_norm))


# ---------------------------------------------------------------------------
# Evaluation (shared jit pool — no stray jits)
# ---------------------------------------------------------------------------

_JITS = _JitCache()


def _predict(params, batch, *, cfg: ModelConfig):
    return jnp.argmax(registry.logits_fn(params, cfg, batch), axis=-1)


def evaluate(params, cfg: ModelConfig, batches) -> float:
    """Top-1 accuracy over batches (per-clip for resnet3d, per-token for
    LM families). Predictions compute on device; one explicit transfer
    per batch reads them back."""
    hits = tot = 0
    for batch in batches:
        pred = _JITS.call(("eval", cfg), functools.partial(_predict, cfg=cfg),
                          (), (params, batch))
        pred = np.asarray(jax.device_get(pred))
        hits += int(np.sum(pred == np.asarray(batch["labels"])))
        tot += int(np.prod(np.shape(batch["labels"])))
    return hits / max(tot, 1)


# ---------------------------------------------------------------------------
# Chain driver (teacher -> TA* -> student)
# ---------------------------------------------------------------------------

@dataclass
class StageResult:
    teacher: str
    student: str
    losses: list = field(default_factory=list)
    accuracy: float = 0.0
    wall_time_s: float = 0.0
    flops_fwd_teacher: float = 0.0
    flops_step_student: float = 0.0
    compiles: int = 0


def _run_epochs(run_epoch, it, total_steps: int, epoch_len: int):
    """Drive scan epochs over an iterator: stack up to ``epoch_len``
    batches, run one program, one host sync for the loss vector. Returns
    the collected per-step losses (list of float)."""
    from repro.data import stack_batches
    losses: list = []
    remaining = total_steps
    while remaining > 0:
        stacked = stack_batches(it, limit=min(epoch_len, remaining))
        if stacked is None:
            break                      # iterator exhausted early
        h = int(jax.tree_util.tree_leaves(stacked)[0].shape[0])
        remaining -= h
        ls = run_epoch(stacked)
        losses.extend(float(x) for x in np.asarray(jax.device_get(ls)))
    return losses


def run_chain(chain: Sequence[ModelConfig], dcfg: DistillConfig,
              train_batches: Callable[[], list], eval_batches: list,
              steps_per_stage: int, seed: int = 0,
              teacher_params=None, kd_kernel: str = "pallas",
              trained_teacher_steps: int = 0,
              epoch_len: int | None = None):
    """Run the teacher -> TA* -> student distillation chain.

    chain[0] is the (pre-)trained teacher; each subsequent model distils
    from the previous stage's result. Each stage runs as scan-epoch
    programs of up to ``epoch_len`` steps (default: the whole stage is one
    program). Returns (final_params, [StageResult]).
    """
    for prev, nxt in zip(chain[:-1], chain[1:]):
        _check_widths(prev, nxt)
    key = jax.random.PRNGKey(seed)
    results = []
    E = epoch_len or max(steps_per_stage, 1)

    # teacher: train from scratch if params not given (server-side pretrain)
    tcfg = chain[0]
    if teacher_params is None:
        teacher_params = registry.init_params(key, tcfg)
        if trained_teacher_steps:
            run = make_scratch_run(tcfg, dcfg)
            state = {"params": teacher_params, "opt": run.opt.init(
                teacher_params)}

            def _pretrain_epoch(stacked):
                state["params"], state["opt"], ls = run.epoch(
                    state["params"], state["opt"], stacked, donate=True)
                return ls

            _run_epochs(_pretrain_epoch, iter(train_batches()),
                        trained_teacher_steps, E)
            teacher_params = state["params"]

    prev_params, prev_cfg = teacher_params, tcfg
    for scfg in chain[1:]:
        key, sub = jax.random.split(key)
        params = registry.init_params(sub, scfg)
        engine = make_distill_engine(prev_cfg, scfg, dcfg,
                                     kd_kernel=kd_kernel)
        state = {"params": params, "opt": engine.opt.init(params)}
        res = StageResult(teacher=prev_cfg.name, student=scfg.name)
        t0 = time.perf_counter()

        def _kd_epoch(stacked, _teacher=prev_params, _state=state,
                      _engine=engine):
            _state["params"], _state["opt"], ls = _engine.epoch(
                _teacher, _state["params"], _state["opt"], stacked,
                donate=True)
            return ls

        res.losses = _run_epochs(_kd_epoch, iter(train_batches()),
                                 steps_per_stage, E)
        res.wall_time_s = time.perf_counter() - t0
        res.compiles = engine.num_compiled
        res.accuracy = evaluate(state["params"], scfg, eval_batches)
        results.append(res)
        prev_params, prev_cfg = state["params"], scfg

    return prev_params, results


# ---------------------------------------------------------------------------
# Codistillation across heterogeneous capacities (beyond the paper;
# PAPERS.md: Knowledge Codistillation)
# ---------------------------------------------------------------------------

class CodistillFleet:
    """m peers of heterogeneous capacity co-training on a shared probe
    stream. Each round: (1) every member's logits on the round's probe
    stack compute once (one vmapped program per architecture group);
    (2) each member runs a masked KD scan against the mean of its *peers'*
    round-start logits (the codistillation exchange — teacher signals are
    deliberately one round stale, that is the algorithm). Members sharing
    a ModelConfig batch as one program: stacked params, per-member
    iteration budgets H^k as a traced int32 vector (the padded-scan
    pattern), so a 100-member two-architecture fleet compiles like a
    2-member one.

    State (group-stacked params/opt) lives on the fleet; ``round`` mutates
    it and returns the member-major loss matrix (m, H), NaN past each
    member's budget.
    """

    def __init__(self, cfgs: Sequence[ModelConfig], dcfg: DistillConfig,
                 kd_kernel: str = "pallas", clip_norm: float = 1.0):
        if len(cfgs) < 2:
            raise ValueError("codistillation needs >= 2 members")
        if kd_kernel not in KD_KERNELS:
            raise ValueError(
                f"kd_kernel must be one of {KD_KERNELS}, got {kd_kernel!r}")
        for other in cfgs[1:]:
            _check_widths(cfgs[0], other)
        fam0 = _probe_family(cfgs[0])
        for c in cfgs[1:]:
            if _probe_family(c) != fam0:
                raise ValueError(
                    "codistillation members must share a probe batch "
                    f"format: {cfgs[0].family} vs {c.family}")
        self.cfgs = tuple(cfgs)
        self.dcfg = dcfg
        self.kd_kernel = kd_kernel
        self.clip_norm = clip_norm
        self.opt = sgd(dcfg.lr, dcfg.momentum, dcfg.weight_decay)
        # group members by architecture: cfg -> member indices
        groups: dict = {}
        for i, c in enumerate(cfgs):
            groups.setdefault(c, []).append(i)
        self.groups = [(c, tuple(idx)) for c, idx in groups.items()]
        self._params = [None] * len(self.groups)   # group-stacked pytrees
        self._opt = [None] * len(self.groups)
        self._jits = _JitCache()

    @property
    def num_members(self) -> int:
        return len(self.cfgs)

    @property
    def num_compiled(self) -> int:
        return self._jits.num_compiled

    def init(self, key):
        for gi, (cfg, idx) in enumerate(self.groups):
            keys = jax.random.split(jax.random.fold_in(key, gi), len(idx))
            members = [registry.init_params(k, cfg) for k in keys]
            self._params[gi] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *members)
            self._opt[gi] = jax.vmap(self.opt.init)(self._params[gi])
        return self

    def member_params(self, i: int):
        """Unstack member i's params (eager slice — a reporting path)."""
        for gi, (cfg, idx) in enumerate(self.groups):
            if i in idx:
                j = idx.index(i)
                return jax.tree_util.tree_map(
                    lambda a: a[j], self._params[gi])
        raise IndexError(i)

    # -- traced cores ----------------------------------------------------
    def _group_logits(self, gparams, stacked, *, cfg):
        def one(p):
            return jax.vmap(
                lambda b: registry.logits_fn(p, cfg, b))(stacked)

        return jax.vmap(one)(gparams)          # (m_g, H, ...logits)

    def _group_kd(self, gparams, gopt, stacked, iters, sum_logits,
                  own_logits, *, cfg, n_total):
        """Per-group masked KD scan: teacher = mean of the *other* members'
        logits, (Σ_all - own) / (n-1); steps past each member's H^k are
        identity on the carry (the fed engine's padded-scan pattern)."""
        H = jax.tree_util.tree_leaves(stacked)[0].shape[0]

        def one(params, opt_state, own, n_iters):
            # n_total is partial-bound static python: trace-time constant
            teacher_seq = (sum_logits - own) / (n_total - 1.0)

            def body(carry, xs):
                i, batch, t_logits = xs
                params, opt_state = carry

                def loss_fn(p):
                    logits = registry.logits_fn(p, cfg, batch)
                    return kd_loss(logits, t_logits, batch["labels"],
                                   self.dcfg.alpha,
                                   temperature=self.dcfg.temperature,
                                   kd_kernel=self.kd_kernel)

                loss, grads = jax.value_and_grad(loss_fn)(params)
                grads = clip_by_global_norm(grads, self.clip_norm)
                new_params, new_opt = self.opt.update(
                    grads, opt_state, params)
                active = i < n_iters
                params, opt_state = jax.tree_util.tree_map(
                    lambda new, old: jnp.where(active, new, old),
                    (new_params, new_opt), (params, opt_state))
                return (params, opt_state), jnp.where(active, loss, jnp.nan)

            (params, opt_state), losses = jax.lax.scan(
                body, (params, opt_state),
                (jnp.arange(H, dtype=jnp.int32), stacked, teacher_seq))
            return params, opt_state, losses

        return jax.vmap(one)(gparams, gopt, own_logits, iters)

    def round(self, stacked_probe, iters=None):
        """One codistillation round over a probe stack (leaves (H, B, ...)).

        ``iters``: (m,) per-member iteration budgets (default: all run the
        full H). Warm rounds at a fixed (H, batch) shape compile nothing.
        Returns the member-major loss matrix (m, H).
        """
        H = int(jax.tree_util.tree_leaves(stacked_probe)[0].shape[0])
        m = self.num_members
        if iters is None:
            iters = np.full((m,), H, np.int32)
        iters = np.asarray(iters, np.int32)
        if iters.shape != (m,):
            raise ValueError(f"iters must be ({m},), got {iters.shape}")

        # (1) round-start logits, one program per architecture group
        group_logits = []
        for gi, (cfg, idx) in enumerate(self.groups):
            group_logits.append(self._jits.call(
                ("logits", gi),
                functools.partial(self._group_logits, cfg=cfg), (),
                (self._params[gi], stacked_probe)))

        # (2) peer-ensemble teacher + masked KD scan per group
        sum_logits = functools.reduce(
            jnp.add, [jnp.sum(gl, axis=0) for gl in group_logits])
        losses = [None] * m
        for gi, (cfg, idx) in enumerate(self.groups):
            g_iters = jnp.asarray(iters[list(idx)], jnp.int32)
            self._params[gi], self._opt[gi], g_losses = self._jits.call(
                ("kd", gi),
                functools.partial(self._group_kd, cfg=cfg, n_total=m), (),
                (self._params[gi], self._opt[gi], stacked_probe, g_iters,
                 sum_logits, group_logits[gi]))
            for j, i in enumerate(idx):
                losses[i] = g_losses[j]
        return jnp.stack(losses)


def _probe_family(cfg: ModelConfig) -> str:
    """Probe-batch format class: members must agree to share batches."""
    if cfg.family == "resnet3d":
        return "clips"
    if cfg.family in registry.ENCDEC_FAMILIES:
        return "src+tokens"
    return "tokens"


def run_codistill(cfgs: Sequence[ModelConfig], dcfg: DistillConfig,
                  train_batches: Callable[[], list], eval_batches: list,
                  rounds: int, steps_per_round: int, iters=None,
                  seed: int = 0, kd_kernel: str = "pallas"):
    """Convenience driver: ``rounds`` codistillation rounds of
    ``steps_per_round`` probe batches each. Returns
    ``(fleet, {"losses": (rounds, m, H) float array, "accuracy": [m]})``.
    """
    from repro.data import stack_batches
    fleet = CodistillFleet(cfgs, dcfg, kd_kernel=kd_kernel).init(
        jax.random.PRNGKey(seed))
    it = iter(train_batches())
    history = []
    for _ in range(rounds):
        stacked = stack_batches(it, limit=steps_per_round)
        if stacked is None:
            it = iter(train_batches())      # fresh pass over the stream
            stacked = stack_batches(it, limit=steps_per_round)
            if stacked is None:
                break
        history.append(np.asarray(jax.device_get(
            fleet.round(stacked, iters=iters))))
    accs = [evaluate(fleet.member_params(i), cfgs[i], eval_batches)
            for i in range(len(cfgs))]
    return fleet, {"losses": np.asarray(history), "accuracy": accs}


# ---------------------------------------------------------------------------
# Analytic chain-time model (Table I/II reproduction at full scale)
# ---------------------------------------------------------------------------

def _fwd_flops_per_item(cfg: ModelConfig) -> float:
    """Forward FLOPs per clip/token. CNNs reuse conv weights spatially, so
    per-clip cost is 2*MACs, not 2*params."""
    if cfg.family == "resnet3d":
        from repro.models.resnet3d import macs_per_clip
        return 2.0 * macs_per_clip(cfg)
    return 2.0 * cfg.param_count()


def stage_flops(teacher: ModelConfig, student: ModelConfig,
                tokens_or_clips: float) -> float:
    """FLOPs of one KD stage: teacher fwd + student fwd/bwd (3x fwd)."""
    return (_fwd_flops_per_item(teacher) + 3 * _fwd_flops_per_item(student)) \
        * tokens_or_clips


def chain_time_model(chain: Sequence[ModelConfig], dataset_items: float,
                     epochs: int, device_flops: float = 125e12,
                     mfu: float = 0.15) -> dict:
    # defaults model the paper's V100 server (125 TF/s tensor peak at a
    # CNN-typical 15% utilization); pass 197e12/0.4 for TPU v5e estimates.
    """Predicted wall time per stage and total (seconds).

    Reproduces the *shape* of Table I (time grows sharply with more TAs
    while accuracy saturates) and its order of magnitude.
    """
    out = {"stages": [], "total_s": 0.0}
    for t, s in zip(chain[:-1], chain[1:]):
        fl = stage_flops(t, s, dataset_items * epochs)
        sec = fl / (device_flops * mfu)
        out["stages"].append({"teacher": t.name, "student": s.name,
                              "flops": fl, "seconds": sec})
        out["total_s"] += sec
    return out
