"""Knowledge distillation with teaching assistants (paper §III-B, §V-A).

L = α·L_cls + (1-α)·L_KD, with L_KD the MSE between teacher and student
logits (the paper's choice — *not* temperature-softened KL). In TA stages the
classification targets are the teacher's hard predictions ("the ground truth
[is] the output of the teacher for the input x").

``run_chain`` executes the full teacher → TA* → student pipeline over any
models in the registry; the hot loss is available both as pure jnp and as the
fused Pallas kernel (kernels/kd_loss.py) via ``use_kernel=True``.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.models.common import cross_entropy
from repro.optim import sgd
from repro.types import DistillConfig, ModelConfig


def kd_loss(student_logits, teacher_logits, labels, alpha: float,
            use_kernel: bool = False):
    """α·CE(student, labels) + (1-α)·MSE(student, teacher) (paper §III-B)."""
    if use_kernel:
        from repro.kernels import ops
        return ops.kd_loss(student_logits, teacher_logits, labels, alpha)
    s = student_logits.astype(jnp.float32)
    t = teacher_logits.astype(jnp.float32)
    l_kd = jnp.mean(jnp.sum(jnp.square(s - t), axis=-1))
    l_cls = cross_entropy(s, labels)
    return alpha * l_cls + (1.0 - alpha) * l_kd


def clip_by_global_norm(grads, max_norm: float):
    sq = jax.tree_util.tree_map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads)
    gn = jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq, jnp.float32(0)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype),
                                  grads)


def make_distill_step(student_cfg: ModelConfig, dcfg: DistillConfig,
                      use_kernel: bool = False,
                      use_teacher_targets: bool = True,
                      clip_norm: float = 1.0):
    """Returns a jitted step: (params, opt_state, batch, teacher_logits) ->
    (params, opt_state, loss). Teacher logits are *inputs* (precomputed by a
    forward pass of the frozen teacher), matching the paper's pipeline where
    KD cost = teacher fwd + student fwd/bwd. Gradients are clipped by global
    norm (the raw MSE-on-logits term is scale-unbounded)."""
    opt = sgd(dcfg.lr, dcfg.momentum, dcfg.weight_decay)

    def loss_fn(params, batch, teacher_logits):
        logits = registry.logits_fn(params, student_cfg, batch)
        labels = batch["labels"]
        if use_teacher_targets:
            labels = jnp.argmax(teacher_logits, axis=-1)
        return kd_loss(logits, teacher_logits, labels, dcfg.alpha,
                       use_kernel=use_kernel)

    @jax.jit
    def step(params, opt_state, batch, teacher_logits):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch,
                                                  teacher_logits)
        if clip_norm:
            grads = clip_by_global_norm(grads, clip_norm)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return step, opt


def make_scratch_step(cfg: ModelConfig, dcfg: DistillConfig):
    """Plain CE training step (the paper's 'train from scratch' baseline)."""
    opt = sgd(dcfg.lr, dcfg.momentum, dcfg.weight_decay)

    @jax.jit
    def step(params, opt_state, batch):
        def loss_fn(p):
            return registry.loss_fn(p, cfg, batch, remat=False)[0]
        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return step, opt


@dataclass
class StageResult:
    teacher: str
    student: str
    losses: list = field(default_factory=list)
    accuracy: float = 0.0
    wall_time_s: float = 0.0
    flops_fwd_teacher: float = 0.0
    flops_step_student: float = 0.0


def evaluate(params, cfg: ModelConfig, batches) -> float:
    """Top-1 accuracy over batches (per-clip for resnet3d)."""
    hits = tot = 0
    logits_j = jax.jit(functools.partial(registry.logits_fn, cfg=cfg))
    for batch in batches:
        logits = logits_j(params=params, batch=batch)
        pred = jnp.argmax(logits, axis=-1)
        hits += int(jnp.sum(pred == batch["labels"]))
        tot += int(np.prod(batch["labels"].shape))
    return hits / max(tot, 1)


def run_chain(chain: Sequence[ModelConfig], dcfg: DistillConfig,
              train_batches: Callable[[], list], eval_batches: list,
              steps_per_stage: int, seed: int = 0,
              teacher_params=None, use_kernel: bool = False,
              trained_teacher_steps: int = 0):
    """Run the teacher -> TA* -> student distillation chain.

    chain[0] is the (pre-)trained teacher; each subsequent model distils from
    the previous stage's result. Returns (final_params, [StageResult]).
    """
    key = jax.random.PRNGKey(seed)
    results = []

    # teacher: train from scratch if params not given (server-side pretrain)
    tcfg = chain[0]
    if teacher_params is None:
        teacher_params = registry.init_params(key, tcfg)
        if trained_teacher_steps:
            step, opt = make_scratch_step(tcfg, dcfg)
            st = opt.init(teacher_params)
            for i, batch in zip(range(trained_teacher_steps),
                                train_batches()):
                teacher_params, st, _ = step(teacher_params, st, batch)

    prev_params, prev_cfg = teacher_params, tcfg
    for scfg in chain[1:]:
        if scfg.vocab_size != prev_cfg.vocab_size and \
                scfg.num_classes != prev_cfg.num_classes:
            raise ValueError(
                f"KD needs equal logit width: {prev_cfg.name} vs {scfg.name}")
        key, sub = jax.random.split(key)
        params = registry.init_params(sub, scfg)
        step, opt = make_distill_step(scfg, dcfg, use_kernel=use_kernel)
        opt_state = opt.init(params)
        teacher_logits_j = jax.jit(
            functools.partial(registry.logits_fn, cfg=prev_cfg))
        res = StageResult(teacher=prev_cfg.name, student=scfg.name)
        t0 = time.perf_counter()
        for i, batch in zip(range(steps_per_stage), train_batches()):
            t_logits = teacher_logits_j(params=prev_params, batch=batch)
            params, opt_state, loss = step(params, opt_state, batch, t_logits)
            res.losses.append(float(loss))
        res.wall_time_s = time.perf_counter() - t0
        res.accuracy = evaluate(params, scfg, eval_batches)
        results.append(res)
        prev_params, prev_cfg = params, scfg

    return prev_params, results


# ---------------------------------------------------------------------------
# Analytic chain-time model (Table I/II reproduction at full scale)
# ---------------------------------------------------------------------------

def _fwd_flops_per_item(cfg: ModelConfig) -> float:
    """Forward FLOPs per clip/token. CNNs reuse conv weights spatially, so
    per-clip cost is 2*MACs, not 2*params."""
    if cfg.family == "resnet3d":
        from repro.models.resnet3d import macs_per_clip
        return 2.0 * macs_per_clip(cfg)
    return 2.0 * cfg.param_count()


def stage_flops(teacher: ModelConfig, student: ModelConfig,
                tokens_or_clips: float) -> float:
    """FLOPs of one KD stage: teacher fwd + student fwd/bwd (3x fwd)."""
    return (_fwd_flops_per_item(teacher) + 3 * _fwd_flops_per_item(student)) \
        * tokens_or_clips


def chain_time_model(chain: Sequence[ModelConfig], dataset_items: float,
                     epochs: int, device_flops: float = 125e12,
                     mfu: float = 0.15) -> dict:
    # defaults model the paper's V100 server (125 TF/s tensor peak at a
    # CNN-typical 15% utilization); pass 197e12/0.4 for TPU v5e estimates.
    """Predicted wall time per stage and total (seconds).

    Reproduces the *shape* of Table I (time grows sharply with more TAs
    while accuracy saturates) and its order of magnitude.
    """
    out = {"stages": [], "total_s": 0.0}
    for t, s in zip(chain[:-1], chain[1:]):
        fl = stage_flops(t, s, dataset_items * epochs)
        sec = fl / (device_flops * mfu)
        out["stages"].append({"teacher": t.name, "student": s.name,
                              "flops": fl, "seconds": sec})
        out["total_s"] += sec
    return out
