"""Event-driven simulator of the heterogeneous embedded-device fleet.

The paper's testbed (Table IV/V) is four NVIDIA Jetson device types whose
per-epoch times differ by up to 4.7×. We cannot run Jetsons here, so the
simulator advances a *virtual clock* using the measured per-epoch times
while executing *real* JAX updates on synthetic data. This reproduces both
the learning dynamics (accuracy curves, staleness distribution) and the
wall-clock claims (async ≈ 40% faster than sync, Table II).

Fleets are described by ``core.fleet``: a resident ``Fleet.from_lists``
for small explicit fleets (the paper's four Jetsons), or a streaming
``FleetSpec`` for populations up to 10^6 clients — a sampled client's
profile, loader and H^k materialize on demand and are released when the
client leaves the sampled/in-flight set, so resident state is O(sampled),
never O(population). Per-round subsampling (sync) and a bounded in-flight
set (async) are switched by ``fed.clients_per_round``; see docs/fleet.md.

Device profiles are the paper's measurements; custom fleets are supported.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import algorithms, fed_engine, fedasync, fedavg
from repro.core.compression import roundtrip
from repro.core.fedasync import ServerState
# DeviceProfile and the Jetson fleets live in core/fleet now; re-exported
# here so existing imports keep working.
from repro.core.fleet import (ASYNC_ENGINES, SYNC_ENGINES, DeviceProfile,
                              EngineSpec, Fleet, FleetSpec,
                              JETSON_FLEET_HMDB51, JETSON_FLEET_UCF101)
from repro.data.synthetic import stack_batches
from repro.optim import trainable_mask
from repro.types import FedConfig, ModelConfig

__all__ = [
    "DeviceProfile", "JETSON_FLEET_HMDB51", "JETSON_FLEET_UCF101",
    "Fleet", "FleetSpec", "EngineSpec", "TraceEvent", "SimResult",
    "Scheduler", "run_async", "run_sync", "analytic_speedup",
]


@dataclass
class TraceEvent:
    time: float
    kind: str            # "dispatch" | "receive" | "round"
    client: int
    global_epoch: int
    staleness: int = 0
    beta_t: float = 0.0
    loss: float = math.nan


@dataclass
class SimResult:
    wall_clock_s: float
    history: list            # (virtual_time, global_epoch, loss)
    trace: list = field(default_factory=list)
    params: object = None
    staleness_hist: dict = field(default_factory=dict)
    # receive-group sizes drained per window (async): {group_size: count}.
    # window=0 is always {1: global_epochs}.
    group_hist: dict = field(default_factory=dict)
    # Scheduler heap high-water mark (async): the arrival model's resident
    # state, asserted O(in-flight) — not O(population) — by the fleet tests.
    max_inflight: int = 0

    @property
    def final_loss(self) -> float:
        return self.history[-1][2] if self.history else math.nan


def _client_time(profile: DeviceProfile, local_iters: int,
                 iters_per_epoch: int, rng: np.random.Generator,
                 jitter: float) -> float:
    epochs = local_iters / max(iters_per_epoch, 1)
    t = profile.epoch_seconds * epochs + profile.upload_seconds
    if jitter:
        # E[lognormal(μ, σ)] = exp(μ + σ²/2); μ = -σ²/2 makes the
        # multiplier mean-one so jitter does not inflate wall-clocks.
        t *= float(rng.lognormal(mean=-0.5 * jitter * jitter, sigma=jitter))
    return t


class Scheduler:
    """Virtual-clock event queue for the async simulator.

    Wraps the ``(finish_time, seq, client, w_new, τ, loss)`` heapq that
    used to live inline in ``run_async`` and owns the *staleness-bounded
    micro-batching window*: ``pop_window`` returns the earliest pending
    receive plus every later receive that

      (a) finishes within ``window`` virtual seconds of it,
      (b) would be applied at unclamped staleness ≤ ``max_staleness``
          given its position in the group (the i-th receive of a group
          started at global epoch t lands at epoch t+i), and
      (c) fits the remaining global-epoch ``budget``.

    ``policy`` decides what happens when an in-window event fails (b):
    ``"skip"`` (default) leaves it in the queue and keeps scanning — a
    later in-window receive at *lower* staleness can still legally join
    the group; ``"stop"`` is the legacy behavior that ended the whole
    group at the first too-stale event (kept reachable as the parity
    oracle). A skipped event is not lost: it leads (or joins) a later
    group, where Algorithm 1's clamp applies as usual.

    ``window <= 0`` degenerates to pop-one — exactly the legacy
    event-by-event loop, including its tie handling (two receives sharing
    a finish time still apply as two separate groups).

    This heap is also the population-scale arrival model: only dispatched
    (in-flight) clients have entries, so a 10^6-client population with an
    in-flight set of m costs O(m) heap entries — receive interarrivals
    are drawn from the superposition of the m in-flight clients' virtual
    finish-time processes, never from per-population state.
    ``max_inflight`` records the high-water mark (asserted O(in-flight)
    by the fleet tests and bench).
    """

    def __init__(self, window: float = 0.0, policy: str = "skip"):
        if policy not in ("skip", "stop"):
            raise ValueError(
                f"policy must be 'skip' or 'stop', got {policy!r}")
        self.window = float(window)
        self.policy = policy
        self._events: list = []
        self._seq = 0
        self.max_inflight = 0

    def push(self, finish_time: float, client: int, w_new, tau: int,
             loss: float) -> None:
        heapq.heappush(self._events,
                       (finish_time, self._seq, client, w_new, tau, loss))
        self._seq += 1
        self.max_inflight = max(self.max_inflight, len(self._events))

    def __len__(self) -> int:
        return len(self._events)

    def pop_window(self, t: int, max_staleness: int, budget: int) -> list:
        """Drain one receive group; see the class docstring for the rules.

        Returns a list of ``(finish_time, client, w_new, τ, loss)`` in
        virtual-time order (heap order), never empty, never longer than
        ``budget``.
        """
        ft, _, k, w_new, tau, loss = heapq.heappop(self._events)
        group = [(ft, k, w_new, tau, loss)]
        if self.window > 0:
            deadline = ft + self.window
            skipped = []
            while self._events and len(group) < budget:
                if self._events[0][0] > deadline:
                    break
                ev = heapq.heappop(self._events)
                if (t + len(group)) - ev[4] > max_staleness:
                    # admitting it here would exceed Assumption 3
                    skipped.append(ev)
                    if self.policy == "stop":
                        break        # legacy: first stale event ends group
                    continue         # skip: a fresher later event may join
                ft, _, k, w_new, tau, loss = ev
                group.append((ft, k, w_new, tau, loss))
            for ev in skipped:
                heapq.heappush(self._events, ev)
        return group


# ---------------------------------------------------------------------------
# Asynchronous (paper Algorithm 1)
# ---------------------------------------------------------------------------

def run_async(params0, cfg: ModelConfig, fed: FedConfig,
              fleet,
              client_data: Optional[Sequence[Callable[[], Iterable]]] = None,
              iters_per_epoch: int = 1, jitter: float = 0.0,
              eval_fn: Optional[Callable] = None,
              eval_every: int = 10, engine="scan",
              window: float = 0.0,
              window_policy: str = "skip", algorithm=None) -> SimResult:
    """Virtual-clock run of asynchronous federated learning.

    ``fleet`` is a ``core.fleet.Fleet`` (or a ``FleetSpec``, which is
    wrapped): each client's ``DeviceProfile``, fresh-iterator factory and
    H^k come from it. The legacy two-sequence signature —
    ``fleet: Sequence[DeviceProfile]`` plus ``client_data:
    Sequence[Callable]`` — still works through a deprecation shim
    (``Fleet.resolve``) for one release.

    ``fed.clients_per_round`` bounds the *in-flight set*: 0 (default)
    dispatches the whole population (legacy semantics — every client
    streams updates forever); m > 0 keeps exactly m clients in flight,
    sampling each replacement uniformly from the population minus the
    in-flight set. With a streaming ``FleetSpec`` fleet the resident
    client state (and the Scheduler heap) then stays O(m) however large
    the population — receive events arrive from the superposition of the
    m in-flight clients' finish-time processes.

    ``engine``: "scan" (default) runs each client's H local iterations as
    one compiled ``lax.scan`` program (core/fed_engine.py) — one dispatch
    and one host sync per *update* instead of per *iteration* — and
    batches *concurrent* dispatches (the fleet-wide kickoff, or any burst
    sharing one server state) into a single padded vmap program even
    though each client has its own H^k: stacks pad to H_max and the
    engine's iteration mask absorbs the difference. "loop" is the legacy
    per-iteration path, kept as a parity oracle. The accepted set is
    defined once, in ``core.fleet.EngineSpec``. The event-driven virtual
    clock is identical under both.

    ``window`` (virtual seconds) is the staleness-bounded micro-batching
    window: receives finishing within ``window`` of the earliest pending
    one — and whose staleness at their position in the group stays ≤
    ``fed.max_staleness`` — drain together (``Scheduler.pop_window``;
    ``window_policy`` picks between skipping a too-stale event, the
    default, and the legacy stop-at-first behavior). The group applies to
    the server as ONE fused sequential mix
    (``fedasync.server_receive_many``: a ``lax.scan`` over the stacked
    ``(w_new, β_t)``, preserving Algorithm 1's mixing order), and the
    group's re-dispatches burst through the padded batched engine as ONE
    program — steady-state async then runs the same compile-cache-friendly
    hot path as the kickoff. The virtual-clock cost of a window is that a
    grouped client idles until the group's last receive before picking up
    its next model; ``eval_fn`` granularity also coarsens to group
    boundaries. ``window=0`` (default) is the exact event-by-event loop.

    ``algorithm``: a ``core.algorithms.FedAlgorithm`` (or its registry
    name). ``None`` keeps the exact legacy FedProx paths; a stateful
    algorithm threads per-client state through local runs, sends
    ``(w_new, msg)`` over the (scheduler's virtual) wire and mixes with
    ``algorithm.mix`` — the staleness-damped generalization of Algorithm
    1's receive. Updates route through the algorithm's wire codec when
    ``fed.compress_bits`` is set or the algorithm demands it
    (``wire_always``, e.g. low-rank projection).
    """
    fleet = Fleet.resolve(fleet, client_data, fed)
    alg = (algorithms.make_algorithm(algorithm)
           if algorithm is not None else None)
    if alg is not None:
        alg.bind_fleet(fleet)
    stateful = alg is not None and alg.stateful
    espec = EngineSpec.from_str(engine, allowed=ASYNC_ENGINES)
    rng = np.random.default_rng(fed.seed)
    sample_rng = np.random.default_rng((fed.seed, 0xA51C))
    if espec is EngineSpec.SCAN:
        run = fed_engine.make_client_run(cfg, fed, algorithm=alg)
    else:
        step, opt = fedasync.cached_client_step(cfg, fed)
    mask = trainable_mask(params0, fed.trainable)
    mix_many = fedasync.make_batched_server_update(fed)
    server = ServerState(params=params0, t=0)

    # per-client assigned local iteration counts H^k ∈ [H_min, H_max]:
    # slower devices get fewer iterations (the server's resource-aware
    # choice, ``Fleet.iters``) — filled lazily so a sampled run never
    # touches more than the dispatched clients
    H: dict = {}
    inflight: set = set()
    m_inflight = fed.clients_per_round or fleet.population

    sched = Scheduler(window, policy=window_policy)
    trace, history = [], []
    staleness_hist: dict = {}
    group_hist: dict = {}

    def _empty_result(k):
        """Out-of-data client: the unchanged global goes back (stateful
        algorithms still finalize at zero iterations so the msg channel —
        SCAFFOLD's Δc=0, low-rank's capacity — stays well-formed)."""
        if not stateful:
            return (server.params, [])
        st = alg.state_for(k, server.params)
        w, st2, msg = alg.client_finalize(
            server.params, server.params, st, jnp.int32(0),
            alg.ctx_for(server.params), fed)
        alg.store_state(k, st2)
        return ((w, msg), [])

    def _run_clients(ks):
        """Local training for clients ``ks`` from the *current* server
        model. Returns {k: (w_new, losses)} — the w_new slot holds
        ``(w_new, msg)`` for stateful algorithms. Concurrent scan
        dispatches batch as one padded program; the per-client path covers
        the rest (single dispatches, the loop oracle, batches that won't
        pad)."""
        results = {}
        if espec is EngineSpec.SCAN:
            stacks = {k: stack_batches(fleet.data(k)(), limit=H[k])
                      for k in ks}
            live = [k for k in ks if stacks[k] is not None]
            if len(live) > 1:
                try:
                    padded, iters = fed_engine.pad_client_batches(
                        [stacks[k] for k in live],
                        H_max=fed.local_iters_max)
                except ValueError:        # shapes disagree across clients
                    padded = None
                if padded is not None and stateful:
                    w_news, new_states, msgs, loss_arr = run.run_batch(
                        server.params, padded, iters, mask=mask,
                        donate=True,
                        server_ctx=alg.ctx_for(server.params),
                        states=alg.stacked_states(server.params, live),
                        client_ids=live)
                    la = jax.device_get(loss_arr)    # single host sync
                    per_client = run.unstack((w_news, new_states, msgs),
                                             len(live))
                    for j, k in enumerate(live):
                        w, st, msg = per_client[j]
                        alg.store_state(k, st)
                        results[k] = ((w, msg),
                                      [float(la[j, iters[j] - 1])])
                elif padded is not None:
                    w_news, loss_arr = run.run_batch(
                        server.params, padded, iters, mask=mask,
                        donate=True)
                    la = jax.device_get(loss_arr)    # single host sync
                    per_client = run.unstack(
                        w_news, len(live))       # one dispatch, not n×leaves
                    for j, k in enumerate(live):
                        results[k] = (per_client[j],
                                      [float(la[j, iters[j] - 1])])
            for k in ks:
                if k in results:
                    continue
                if stacks[k] is None:            # client out of data
                    results[k] = _empty_result(k)
                elif stateful:
                    w, st, msg, loss_arr = run(
                        server.params, stacks[k], mask=mask, donate=True,
                        server_ctx=alg.ctx_for(server.params),
                        state=alg.state_for(k, server.params))
                    alg.store_state(k, st)
                    results[k] = ((w, msg),
                                  [float(jax.device_get(loss_arr)[-1])])
                else:
                    w_new, loss_arr = run(server.params, stacks[k],
                                          mask=mask, donate=True)
                    # one explicit transfer; indexing happens on host
                    results[k] = (w_new,
                                  [float(jax.device_get(loss_arr)[-1])])
        elif alg is not None:
            for k in ks:
                w_new, st, msg, losses = algorithms.client_update_loop(
                    server.params, fleet.data(k)(), cfg, fed, alg,
                    client_id=k, num_iters=H[k], mask=mask,
                    server_ctx=alg.ctx_for(server.params))
                results[k] = ((w_new, msg) if stateful else w_new, losses)
        else:
            for k in ks:
                w_new, _, losses = fedasync.client_update(
                    server.params, server.t, fleet.data(k)(), cfg, fed,
                    step=step, opt=opt, mask=mask, num_iters=H[k])
                results[k] = (w_new, losses)
        return results

    def dispatch(ks, now: float):
        tau = server.t
        for k in ks:
            if k not in H:
                H[k] = fleet.iters(k, fed)
            inflight.add(k)
        # run the local training NOW (numerically); finish time is virtual
        results = _run_clients(ks)
        for k in ks:
            w_new, losses = results[k]
            if alg is not None and (fed.compress_bits or alg.wire_always):
                # the algorithm's wire codec (int8/int4 deltas, low-rank
                # factors); decode against the anchor the server handed out
                w, msg = w_new if stateful else (w_new, ())
                wire = alg.encode(w, msg, server.params, fed)
                w, msg = alg.decode(wire, server.params, fed)
                w_new = (w, msg) if stateful else w
            elif fed.compress_bits:
                # int8 delta on the wire; server reconstructs against the
                # anchor it handed out (communication-efficient FL, §II)
                w_new, _ = roundtrip(w_new, server.params,
                                     fed.compress_bits)
            dt = _client_time(fleet.profile(k), H[k], iters_per_epoch, rng,
                              jitter)
            sched.push(now + dt, k, w_new, tau,
                       losses[-1] if losses else math.nan)
            trace.append(TraceEvent(now, "dispatch", k, tau))

    if m_inflight < fleet.population:
        kickoff = [int(k) for k in fleet.sample(sample_rng, m_inflight)]
    else:
        kickoff = list(range(fleet.population))
    dispatch(kickoff, 0.0)

    now = 0.0
    while server.t < fed.global_epochs and len(sched):
        group = sched.pop_window(server.t, fed.max_staleness,
                                 fed.global_epochs - server.t)
        t0 = server.t
        if stateful:
            server, new_ctx, stals, betas = fedasync.server_receive_many(
                server, [(w, msg, tau)
                         for _, _, (w, msg), tau, _ in group], fed,
                algorithm=alg, server_ctx=alg.ctx_for(server.params))
            alg.set_ctx(new_ctx)
        else:
            server, stals, betas = fedasync.server_receive_many(
                server, [(w_new, tau) for _, _, w_new, tau, _ in group],
                fed, mix_many=mix_many)
        for i, ((ft, k, _, _, loss), st, bt) in enumerate(
                zip(group, stals, betas)):
            now = ft
            staleness_hist[st] = staleness_hist.get(st, 0) + 1
            trace.append(TraceEvent(ft, "receive", k, t0 + i + 1, st, bt,
                                    loss))
            history.append((ft, t0 + i + 1, loss))
        group_hist[len(group)] = group_hist.get(len(group), 0) + 1
        if eval_fn is not None and any(
                t % eval_every == 0 for t in range(t0 + 1, server.t + 1)):
            # the fused mix has no intermediate params: evaluate once at
            # the group boundary (exact per-epoch cadence at window=0)
            eval_fn(server.t, now, server.params)
        finished = [k for _, k, _, _, _ in group]
        if server.t < fed.global_epochs:
            if m_inflight < fleet.population:
                # population-scale steady state: finished clients leave
                # the in-flight set (their state is released) and fresh
                # clients are sampled from the rest of the population
                inflight.difference_update(finished)
                for k in finished:
                    H.pop(k, None)
                fleet.release(finished)
                replacements = [int(k) for k in fleet.sample(
                    sample_rng, len(finished), exclude=inflight)]
                dispatch(replacements, now)
            else:
                dispatch(finished, now)
        else:
            inflight.difference_update(finished)
            if m_inflight < fleet.population:
                fleet.release(finished)

    return SimResult(wall_clock_s=now, history=history, trace=trace,
                     params=server.params, staleness_hist=staleness_hist,
                     group_hist=group_hist, max_inflight=sched.max_inflight)


# ---------------------------------------------------------------------------
# Synchronous FedAvg baseline
# ---------------------------------------------------------------------------

def run_sync(params0, cfg: ModelConfig, fed: FedConfig,
             fleet,
             client_data: Optional[Sequence[Callable[[], Iterable]]] = None,
             iters_per_epoch: int = 1, jitter: float = 0.0,
             eval_fn: Optional[Callable] = None,
             eval_every: int = 10, engine="scan",
             algorithm=None) -> SimResult:
    """Virtual-clock synchronous FedAvg: each round costs max(client time).

    ``fleet`` is a ``core.fleet.Fleet`` / ``FleetSpec``; the legacy
    (profiles, client_data) sequence pair still works through the
    deprecation shim (see ``run_async``).

    ``fed.clients_per_round`` enables per-round client subsampling: each
    round draws m clients uniformly without replacement, runs them as one
    padded batched program, and (for streaming fleets) releases their
    state afterwards — resident state is O(m) whatever the population.
    A round then advances m global epochs, so
    ``rounds = max(global_epochs // m, 1)``. 0 (default) runs the whole
    population every round, the legacy semantics.

    ``engine="scan"`` (default) runs every round as one vmap-over-clients
    batched program; ``"shard"`` additionally splits the round's client
    axis over this host's device mesh (``launch.mesh.make_fleet_mesh``)
    with shard_map; ``"hier"`` splits it over a two-level
    ``('edge', 'clients')`` mesh — clients reduce to edge aggregators and
    edges to the server as a nested psum, numerically the flat weighted
    average; ``"loop"`` is the legacy per-client loop (parity oracle).
    The accepted set is defined once, in ``core.fleet.EngineSpec``.

    Each round the batched engines donate the incoming global params (the
    new global aliases their buffers; ``params0`` itself is copied once up
    front and never donated), so an ``eval_fn`` must evaluate the params
    it is handed immediately, not stash them for later.

    ``algorithm``: a ``core.algorithms.FedAlgorithm`` (or its registry
    name); ``None`` keeps the exact legacy FedProx round. Stateful
    algorithms persist per-client state on the instance across rounds,
    keyed by the sampled client ids.
    """
    fleet = Fleet.resolve(fleet, client_data, fed)
    alg = (algorithms.make_algorithm(algorithm)
           if algorithm is not None else None)
    if alg is not None:
        alg.bind_fleet(fleet)
    espec = EngineSpec.from_str(engine, allowed=SYNC_ENGINES)
    rng = np.random.default_rng(fed.seed)
    sample_rng = np.random.default_rng((fed.seed, 0x5A3D))
    if espec is EngineSpec.LOOP:
        step, opt = fedasync.cached_client_step(cfg, fed)
        round_engine = None
    else:
        round_engine = espec.build_sync(cfg, fed, algorithm=alg)
    mask = trainable_mask(params0, fed.trainable)
    params = params0
    if round_engine is not None:
        # defensive copy so EVERY round can donate its params under one
        # jit donation signature (a second signature would re-trace and
        # re-compile the whole round program) while the caller's params0
        # stays untouched
        params = jax.tree_util.tree_map(jnp.array, params0)
    now = 0.0
    history, trace = [], []
    m = fed.clients_per_round or fleet.population
    rounds = fed.global_epochs // max(m, 1)
    rounds = max(rounds, 1)
    for r in range(rounds):
        if m < fleet.population:
            ids = [int(k) for k in fleet.sample(sample_rng, m)]
        else:
            ids = list(range(fleet.population))
        batches = [fleet.data(k)() for k in ids]
        if round_engine is not None:
            # the incoming global (our private copy, or the previous
            # round's output) is dead after this call: donate it so the
            # new global reuses its buffers
            params, losses = fedavg.fedavg_round(params, batches, cfg, fed,
                                                 engine=round_engine,
                                                 mask=mask,
                                                 donate_params=True,
                                                 algorithm=alg,
                                                 client_ids=ids)
        else:
            params, losses = fedavg.fedavg_round_loop(
                params, batches, cfg, fed, step=step, opt=opt, mask=mask,
                algorithm=alg, client_ids=ids)
        dt = max(_client_time(fleet.profile(k), fed.local_iters_max,
                              iters_per_epoch, rng, jitter)
                 for k in ids)
        if m < fleet.population:
            fleet.release(ids)
        now += dt
        loss = float(np.mean([l[-1] for l in losses if l]))
        history.append((now, r + 1, loss))
        trace.append(TraceEvent(now, "round", -1, r + 1, 0, 0.0, loss))
        if eval_fn is not None and (r + 1) % eval_every == 0:
            eval_fn(r + 1, now, params)
    return SimResult(wall_clock_s=now, history=history, trace=trace,
                     params=params)


# ---------------------------------------------------------------------------
# Analytic speedup model (reproduces the Table II 40% claim without training)
# ---------------------------------------------------------------------------

def analytic_speedup(fleet: Sequence[DeviceProfile], epochs: int,
                     local_epochs: int = 3) -> dict:
    """Wall-clock for sync vs async on a fleet, ignoring numerics.

    Sync: rounds of max(client); each round consumes n_clients global epochs
    worth of aggregation (one per client). Async: clients stream updates
    independently; the server finishes when `epochs` updates arrived, i.e.
    wall clock ≈ epochs / aggregate_rate.
    """
    n = len(fleet)
    per_update = [p.epoch_seconds * local_epochs + p.upload_seconds
                  for p in fleet]
    rounds = epochs / n
    sync = rounds * max(per_update)
    rate = sum(1.0 / t for t in per_update)       # updates per second
    async_ = epochs / rate
    return {"sync_s": sync, "async_s": async_,
            "reduction": 1.0 - async_ / sync}
