"""Loop-aware HLO cost analysis.

``compiled.cost_analysis()`` counts every while-loop body ONCE — a
layer-scanned transformer therefore under-reports FLOPs/bytes/collectives
by ~num_layers×. This module walks the post-SPMD HLO text, resolves while
trip counts from their condition computations, and accumulates:

  - flops: dot ops (2·|out|·|contraction|), convolutions approximated,
    elementwise ops at 1 flop/element — each × the product of enclosing
    loop trip counts;
  - bytes: HBM traffic estimate = operand + output bytes of every
    *top-level* instruction in control computations (fusions counted at
    their call site, their internals skipped — post-fusion boundaries are
    a reasonable proxy for materialized buffers);
  - collective_bytes per kind (all-reduce doubled: RS+AG ring phases).

Validated against known scans in tests/test_roofline.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(([^)]*)\)\s*->")
_OP_RE = re.compile(r"\s*([\w\-]+)\((.*)$", re.S)


def _split_instr(line: str):
    """'%x = TYPE opcode(args), attrs' -> (name, type_str, opcode, rest)."""
    if line.startswith("ROOT"):
        line = line[4:].lstrip()
    if not line.startswith("%"):
        return None
    eq = line.find(" = ")
    if eq < 0:
        return None
    name = line[1:eq].strip()
    rest = line[eq + 3:].lstrip()
    if rest.startswith("("):                     # tuple type: balance parens
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, tail = rest[:i + 1], rest[i + 1:]
    else:
        m = re.match(r"([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)", rest)
        if not m:
            return None
        type_str, tail = m.group(1), rest[m.end():]
    m = _OP_RE.match(tail)
    if not m:
        return None
    return name, type_str, m.group(1), m.group(2)

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_FREE_OPS = {"tuple", "get-tuple-element", "parameter", "constant",
             "bitcast", "after-all", "partition-id", "replica-id",
             "reshape", "copy-start", "copy-done"}


def _shape_info(type_str: str):
    """-> (bytes, dims_list) for possibly-tuple type strings."""
    total = 0
    all_dims = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        dl = []
        for d in dims.split(","):
            if d:
                dl.append(int(d))
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
        all_dims.append(dl)
    return total, all_dims


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str           # operand list + attrs (raw tail of the line)
    out_bytes: int = 0
    out_dims: list = field(default_factory=list)

    def operands(self):
        # operand names appear before the first `)` closing the op call
        depth = 0
        args = []
        cur = []
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    break
                depth -= 1
            if ch == "," and depth == 0:
                args.append("".join(cur))
                cur = []
            else:
                cur.append(ch)
        args.append("".join(cur))
        names = []
        for a in args:
            m = re.search(r"%([\w.\-]+)", a)
            if m:
                names.append(m.group(1))
        return names


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    params: dict = field(default_factory=dict)   # name -> (bytes, dims)


def _parse_header(line: str):
    """'%name (p: type, ...) -> ret {' -> (name, params_str) or None."""
    body = line
    if body.startswith("ENTRY"):
        body = body[5:].lstrip()
    if not body.startswith("%"):
        return None
    i = body.find("(")
    if i < 0:
        return None
    name = body[1:i].strip()
    depth = 0
    for j in range(i, len(body)):
        if body[j] == "(":
            depth += 1
        elif body[j] == ")":
            depth -= 1
            if depth == 0:
                break
    else:
        return None
    if "->" not in body[j:]:
        return None
    return name, body[i + 1:j]


def parse_hlo(text: str) -> dict:
    comps: dict = {}
    cur = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("//"):
            continue
        if ("{" in line and "=" not in line.split("(")[0]
                and (line.startswith("%") or line.startswith("ENTRY"))):
            hdr = _parse_header(line)
            if hdr:
                cur = Computation(hdr[0])
                comps[cur.name] = cur
                # parameter shapes from the signature (types may be tuples)
                for pm in re.finditer(
                        r"([\w.\-]+):\s*(\((?:[^()]|\([^()]*\))*\)|"
                        r"[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)",
                        hdr[1]):
                    cur.params[pm.group(1)] = _shape_info(pm.group(2))
                continue
        if line.startswith("}"):
            continue
        parts = _split_instr(line)
        if parts and cur is not None:
            name, tstr, opcode, rest = parts
            b, dims = _shape_info(tstr)
            cur.instrs.append(Instr(name, tstr, opcode, rest, b, dims))
    return comps


def _symtab(comp: Computation) -> dict:
    tab = dict(comp.params)
    for ins in comp.instrs:
        tab[ins.name] = (ins.out_bytes, ins.out_dims)
    return tab


def _trip_count(comps: dict, cond_name: str) -> int:
    """Largest scalar integer constant in the condition computation."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant" and "[]" in ins.type_str:
            m = re.search(r"constant\((\d+)\)", "constant(" + ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _num_elems(out_dims) -> float:
    """Total elements across (possibly tuple) output shapes."""
    total = 0
    for dl in out_dims:
        n = 1
        for d in dl:
            n *= d
        total += n
    return float(total)


def _dot_flops(ins: Instr, tab: dict) -> float:
    out_elems = _num_elems(ins.out_dims)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    ops = ins.operands()
    if not m or not ops:
        return 2.0 * out_elems   # conservative
    lhs = tab.get(ops[0])
    if lhs is None or not lhs[1]:
        return 2.0 * out_elems
    lhs_dims = lhs[1][0]
    contract = 1
    for idx in m.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def _conv_flops(ins: Instr, tab: dict) -> float:
    out_elems = _num_elems(ins.out_dims)
    ops = ins.operands()
    if len(ops) < 2 or tab.get(ops[1]) is None or not tab[ops[1]][1]:
        return 2.0 * out_elems
    kdims = tab[ops[1]][1][0]
    k = 1
    for d in kdims[:-1]:          # all but output-feature dim
        k *= d
    return 2.0 * out_elems * k


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: dict = field(default_factory=dict)
    loops: list = field(default_factory=list)    # (body, trip)
    contrib: list = field(default_factory=list)  # (bytes, op, type, mult)
    scoped: dict = field(default_factory=dict)   # scope name -> bytes
    track_top: int = 0

    SCOPES = ("attn_inner",)

    def _track(self, traffic, op, type_str, mult, rest=""):
        if self.track_top:
            self.contrib.append((traffic, op, type_str[:80], mult))
        for sc in self.SCOPES:
            if sc in rest:
                self.scoped[sc] = self.scoped.get(sc, 0.0) + traffic
                break

    def top(self, n=20):
        return sorted(self.contrib, reverse=True)[:n]

    @property
    def collective_bytes(self) -> float:
        return float(sum(self.collectives.values()))


def _traffic(op: str, out_bytes: float, operand_bytes: list) -> float:
    """HBM traffic estimate for one materialized op.

    In-place patterns (dynamic-update-slice, and fusions whose output
    aliases their largest operand — XLA buffer-assigns these in place)
    only move the *update*, not the whole buffer.
    """
    if op == "dynamic-update-slice":
        upd = operand_bytes[1] if len(operand_bytes) > 1 else out_bytes
        return 2.0 * upd
    if op in ("dynamic-slice", "gather"):
        return 2.0 * out_bytes
    total = out_bytes + sum(operand_bytes)
    if op == "fusion" and operand_bytes:
        big = max(operand_bytes)
        if big == out_bytes:          # likely in-place update fusion
            total -= big
    return total


def _operand_traffic(tab, callee, idx: int, name: str) -> float:
    """Bytes actually read from operand ``idx`` of a fusion.

    If the corresponding callee parameter is consumed ONLY by slice /
    dynamic-slice / gather ops, the fusion reads just those windows — not
    the whole buffer (e.g. per-layer reads of a stacked KV cache).
    """
    full = tab.get(name, (0,))[0]
    if callee is None:
        return full
    pnames = list(callee.params.keys())
    if idx >= len(pnames):
        return full
    pname = pnames[idx]
    used = 0.0
    for ins in callee.instrs:
        if f"%{pname}" not in ins.rest and pname not in ins.operands():
            continue
        if ins.opcode in ("slice", "dynamic-slice", "gather"):
            used += ins.out_bytes
        elif ins.opcode in ("parameter", "bitcast", "reshape",
                            "get-tuple-element"):
            continue
        else:
            return full           # some op reads the whole operand
    return min(used, full) if used else full


def _walk(comps, comp_name, mult, cost: HloCost, in_fusion=False,
          visited_stack=()):
    comp = comps.get(comp_name)
    if comp is None or comp_name in visited_stack:
        return
    tab = _symtab(comp)
    for ins in comp.instrs:
        op = ins.opcode
        if op == "while":
            m = re.search(r"condition=%?([\w.\-]+)", ins.rest)
            b = re.search(r"body=%?([\w.\-]+)", ins.rest)
            trip = _trip_count(comps, m.group(1)) if m else 1
            cost.loops.append((b.group(1) if b else "?", trip))
            if b:
                _walk(comps, b.group(1), mult * trip, cost,
                      visited_stack=visited_stack + (comp_name,))
            continue
        if op == "conditional":
            for cal in re.findall(r"%([\w.\-]+)", ins.rest):
                if cal in comps:
                    _walk(comps, cal, mult, cost,
                          visited_stack=visited_stack + (comp_name,))
            continue
        if op in ("fusion", "call"):
            m = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", ins.rest)
            callee = comps.get(m.group(1)) if m else None
            if not in_fusion:
                names = ins.operands()
                opb = [_operand_traffic(tab, callee, i, o)
                       for i, o in enumerate(names)]
                traffic = _traffic(op, ins.out_bytes, opb)
                # in-place DUS fusion: only the update slice moves
                if callee is not None and opb \
                        and max(opb) == ins.out_bytes:
                    if any(i.opcode == "dynamic-update-slice"
                           for i in callee.instrs):
                        traffic = 2.0 * sum(b for b in opb
                                            if b != ins.out_bytes)
                cost.bytes += mult * traffic
                cost._track(mult * traffic, op, ins.type_str, mult,
                            ins.rest)
            if m:
                # descend for dot flops only (internals don't touch HBM)
                _walk(comps, m.group(1), mult, cost, in_fusion=True,
                      visited_stack=visited_stack + (comp_name,))
            continue
        # collectives
        for kind in COLLECTIVES:
            if op == kind or op == kind + "-start":
                factor = 2 if kind == "all-reduce" else 1
                cost.collectives[kind] = cost.collectives.get(kind, 0.0) \
                    + mult * factor * ins.out_bytes
                break
        # flops
        if op == "dot":
            cost.flops += mult * _dot_flops(ins, tab)
        elif op == "convolution":
            cost.flops += mult * _conv_flops(ins, tab)
        elif op not in _FREE_OPS:
            cost.flops += mult * _num_elems(ins.out_dims)
        # bytes (top-level only; fusion internals skipped)
        if not in_fusion and op not in _FREE_OPS:
            opb = [tab.get(o, (0,))[0] for o in ins.operands()]
            traffic = _traffic(op, ins.out_bytes, opb)
            cost.bytes += mult * traffic
            cost._track(mult * traffic, op, ins.type_str, mult, ins.rest)


def analyze_hlo(text: str, track_top: bool = False) -> HloCost:
    comps = parse_hlo(text)
    entry = None
    for line in text.splitlines():
        if line.strip().startswith("ENTRY"):
            m = _HDR_RE.match(line.strip())
            if m:
                entry = m.group(1)
                break
    if entry is None:
        # fall back: computation named main-ish
        entry = next((n for n in comps if "main" in n), None)
    cost = HloCost(track_top=20 if track_top else 0)
    if entry:
        _walk(comps, entry, 1.0, cost)
    return cost
