from repro.roofline.analysis import (HW, RooflineReport, analyze_compiled,
                                     parse_collective_bytes)

__all__ = ["HW", "RooflineReport", "analyze_compiled",
           "parse_collective_bytes"]
