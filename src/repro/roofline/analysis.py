"""Roofline terms from a compiled dry-run artifact (no hardware needed).

    compute_s    = HLO_FLOPs_per_device / peak_FLOP/s
    memory_s     = HLO_bytes_per_device / HBM_bw
    collective_s = collective_bytes_per_device / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` of the SPMD-partitioned
per-device module. Collective bytes are parsed from ``compiled.as_text()``
(collectives only exist after partitioning): we sum output-shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. all-reduce bytes are doubled (reduce-scatter +
all-gather phases of a ring each move ~the full buffer).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field


@dataclass(frozen=True)
class HW:
    """TPU v5e."""
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link
    hbm_bytes: float = 16e9           # capacity per chip


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?:[a-z0-9]+)\[[0-9,]*\][^\s]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes per collective kind from post-SPMD HLO text."""
    out: dict = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(
            r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+"
            r"\[[0-9,]*\]\S*))\s*(all-gather|all-reduce|reduce-scatter|"
            r"all-to-all|collective-permute)(-start)?\(", line)
        if not m:
            continue
        shapes, kind, start = m.group(1), m.group(2), m.group(3)
        total = sum(_shape_bytes(dt, dims)
                    for dt, dims in _SHAPE_RE.findall(shapes))
        mult = 2 if kind == "all-reduce" else 1   # RS+AG phases of the ring
        out.setdefault(kind, 0)
        out[kind] += total * mult
    return out


# ---------------------------------------------------------------------------
# Analytic decode-step byte models (single source of truth for the fused
# Pallas decode kernels' CostEstimates and the fused-vs-einsum benches)
# ---------------------------------------------------------------------------

def attend_decode_bytes(n_ctx: int, kv_heads: int, q_heads: int,
                        head_dim: int, *, dtype_bytes: int = 4,
                        fused: bool = True) -> int:
    """Modeled HBM bytes for ONE decode-attend step of one stream against
    an ``n_ctx``-position cache (a W-slot ring or the first ``k_ext``
    positions of a uniform cache — the model is the same).

    Fused (Pallas) path: one pass over K and V plus the q/out vectors —
    the score/probability tensors live in VMEM.  The einsum path
    additionally materializes the (q_heads, n_ctx) f32 scores and
    probabilities in HBM (one write + one read each), which is exactly
    the traffic the kernel fuses away; ``kernels/swa_attention.py`` feeds
    the fused number to ``pl.CostEstimate`` and
    ``tests/test_roofline.py`` pins both against this function."""
    if n_ctx < 1:
        raise ValueError(f"n_ctx must be >= 1, got {n_ctx}")
    qo = 2 * q_heads * head_dim * dtype_bytes            # q read + out write
    cache = 2 * n_ctx * kv_heads * head_dim * dtype_bytes    # K + V, 1 pass
    total = qo + cache
    if not fused:
        total += 4 * q_heads * n_ctx * 4    # scores + probs, write + read
    return total


def attend_decode_flops(n_ctx: int, q_heads: int, head_dim: int) -> int:
    """MACs*2 for one decode-attend step: q·K plus p·V."""
    return 2 * 2 * q_heads * head_dim * n_ctx


def ssd_decode_bytes(heads: int, head_dim: int, d_state: int, *,
                     dtype_bytes: int = 4, fused: bool = True) -> int:
    """Modeled HBM bytes for ONE fused SSD decode step of one stream:
    the (H, P, N) recurrent state read + written once, plus the x/dt/B/C/y
    vectors.  The einsum path additionally materializes the (H, P, N)
    ``dt·x⊗B`` update tensor in HBM (write + read) before the state
    addition — the traffic ``kernels/ssd_scan.ssd_decode_step_pallas``
    fuses away."""
    state = 2 * heads * head_dim * d_state * dtype_bytes     # read + write
    io = (2 * heads * head_dim + 2 * d_state + 2 * heads) * dtype_bytes
    total = state + io
    if not fused:
        total += 2 * heads * head_dim * d_state * 4   # upd, write + read
    return total


def ssd_decode_flops(heads: int, head_dim: int, d_state: int) -> int:
    """One SSD decode step: state decay + rank-1 update + C readout."""
    return (3 * heads * head_dim * d_state
            + 2 * heads * head_dim * d_state)


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    collectives: dict
    peak_memory_bytes: float
    model_flops_global: float      # 6·N_active·D
    hw: HW = field(default_factory=HW)
    xla_cost: dict = field(default_factory=dict)
    loops: list = field(default_factory=list)
    attn_intermediate_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / self.hw.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / self.hw.hbm_bw

    @property
    def memory_s_kernelized(self) -> float:
        """Memory term if the jnp attention were the Pallas flash kernel:
        score/probability tensors stay in VMEM; ~5% of their traffic remains
        as the kernel's own q/k/v/o streaming (conservative)."""
        b = self.bytes_per_device - 0.95 * self.attn_intermediate_bytes
        return b / self.hw.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / self.hw.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Simple max-of-terms roofline step estimate."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / compiled FLOPs (remat & redundancy waste)."""
        total = self.flops_per_device * self.chips
        return self.model_flops_global / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        denom = self.step_time_s * self.hw.peak_flops * self.chips
        return self.model_flops_global / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes": self.collective_bytes,
            "collectives": self.collectives,
            "peak_memory_bytes": self.peak_memory_bytes,
            "model_flops_global": self.model_flops_global,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "useful_flop_ratio": self.useful_flop_ratio, "mfu": self.mfu,
            "xla_cost": self.xla_cost, "loops": self.loops,
            "attn_intermediate_bytes": self.attn_intermediate_bytes,
            "memory_s_kernelized": self.memory_s_kernelized,
        }


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     chips: int, model_flops_global: float,
                     hw: HW = HW()) -> RooflineReport:
    """FLOPs/bytes/collectives via the loop-aware HLO walker (hlo.py).

    ``compiled.cost_analysis()`` counts while-loop bodies once — useless for
    layer-scanned models — so the walker resolves trip counts itself; the
    raw XLA numbers are kept in ``xla_cost`` for comparison.
    """
    from repro.roofline.hlo import analyze_hlo
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older jax returns [dict]
        cost = cost[0]
    mem = compiled.memory_analysis()
    peak = float(getattr(mem, "temp_size_in_bytes", 0)
                 + getattr(mem, "argument_size_in_bytes", 0)
                 + getattr(mem, "output_size_in_bytes", 0)
                 - getattr(mem, "alias_size_in_bytes", 0))
    hc = analyze_hlo(compiled.as_text())
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=hc.flops, bytes_per_device=hc.bytes,
        collective_bytes=hc.collective_bytes, collectives=dict(hc.collectives),
        peak_memory_bytes=peak, model_flops_global=model_flops_global,
        hw=hw)
    rep.xla_cost = {"flops": float(cost.get("flops", 0.0)),
                    "bytes_accessed": float(cost.get("bytes accessed", 0.0))}
    rep.loops = list(hc.loops)
    rep.attn_intermediate_bytes = float(hc.scoped.get("attn_inner", 0.0))
    return rep
