"""Learning-rate schedules (step -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def cosine(lr: float, total_steps: int, warmup: int = 0,
           final_frac: float = 0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1),
                     0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return fn


def inverse_sqrt(lr: float, warmup: int = 100):
    """η = lr/√max(step, warmup) — the theorem's η = 1/√E choice."""
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        return lr / jnp.sqrt(jnp.maximum(step, warmup))
    return fn
