"""The paper's proximal local objective (§III-D):

    g_{w_t}(w; d) = l(w; d) + (θ/2)·||w - w_t||²

so ∇g = ∇l + θ·(w - w_t). ``proximal_grad`` adds the regularization term to
plain task gradients given the global anchor w_t.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def proximal_grad(grads, params, anchor, theta: float):
    if theta == 0.0:
        return grads
    return jax.tree_util.tree_map(
        lambda g, p, a: g + theta * (p.astype(jnp.float32)
                                     - a.astype(jnp.float32)).astype(g.dtype),
        grads, params, anchor)


def control_variate_grad(grads, c, c_k):
    """SCAFFOLD drift correction (Karimireddy et al. 2020, Alg. 1 line 10):
    g ← g + c − c_k, with the variates accumulated in f32 and the result
    cast back to the gradient dtype. Composes after ``proximal_grad`` —
    the paper's proximal term and the control variate are independent
    corrections to the same local gradient."""
    return jax.tree_util.tree_map(
        lambda g, a, b: (g.astype(jnp.float32) + a - b).astype(g.dtype),
        grads, c, c_k)


def proximal_penalty(params, anchor, theta: float):
    """(θ/2)·||w - w_t||² as a scalar (for logging / loss reporting)."""
    if theta == 0.0:
        return jnp.float32(0.0)
    sq = jax.tree_util.tree_map(
        lambda p, a: jnp.sum(jnp.square(p.astype(jnp.float32)
                                        - a.astype(jnp.float32))),
        params, anchor)
    return 0.5 * theta * jax.tree_util.tree_reduce(jnp.add, sq, jnp.float32(0))
