"""Minimal optax-style optimizers (no external deps).

An Optimizer is (init, update): update(grads, state, params) ->
(new_params, new_state). The paper's setting is SGD with momentum 0.9 and
weight decay (0.001 for KD, 0 for fine-tuning).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def _tree_zeros(params):
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def sgd(lr, momentum: float = 0.0, weight_decay: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    """lr: float or callable step -> lr."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        mom = _tree_zeros(params) if momentum else None
        return {"mom": mom, "step": jnp.int32(0)}

    def update(grads, state, params):
        step = state["step"]
        eta = lr_fn(step)
        if weight_decay:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p, grads, params)
        if momentum:
            mom = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, state["mom"], grads)
            eff = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, mom, grads) if nesterov else mom
            new_state = {"mom": mom, "step": step + 1}
        else:
            eff = grads
            new_state = {"mom": None, "step": step + 1}
        new_params = jax.tree_util.tree_map(
            lambda p, g: (p - eta * g).astype(p.dtype), params, eff)
        return new_params, new_state

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"m": _tree_zeros(params), "v": _tree_zeros(params),
                "step": jnp.int32(0)}

    def update(grads, state, params):
        step = state["step"] + 1
        eta = lr_fn(step)
        m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                   state["m"], grads)
        v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                   state["v"], grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                u = u + weight_decay * p
            return (p - eta * u).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Trainable masks — the paper fine-tunes only the final FC layer (§V-B)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _mask_leaves_for(treedef, mode: str):
    """Per-leaf 0/1 mask values, cached by (treedef, mode).

    The mask depends only on the tree *structure* (key paths), which is
    hashable — the federated hot path rebuilds masks per client run, so
    the python tree walk is paid once per (model, mode), not per call.
    """
    dummy = jax.tree_util.tree_unflatten(treedef,
                                         list(range(treedef.num_leaves)))
    head_keys = {"fc", "lm_head", "final_norm", "enc_norm"}
    tied = "lm_head" not in dummy and "fc" not in dummy
    if tied:
        head_keys = head_keys | {"embed"}

    paths = jax.tree_util.tree_flatten_with_path(dummy)[0]

    def leaf_mask(path_leaf):
        path, _ = path_leaf
        top = path[0].key if hasattr(path[0], "key") else str(path[0])
        return 1.0 if top in head_keys else 0.0

    return tuple(leaf_mask(pl) for pl in paths)


def trainable_mask(params, mode: str = "all"):
    """Pytree of 0/1 floats. mode: 'all' | 'last_layer'.

    'last_layer' keeps the classifier head trainable: 'fc' (resnet3d),
    'lm_head' (untied LMs) or 'embed' + 'final_norm' (tied LMs).
    """
    if mode == "all":
        return jax.tree_util.tree_map(lambda _: 1.0, params)
    if mode != "last_layer":
        raise ValueError(mode)
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(treedef,
                                        list(_mask_leaves_for(treedef, mode)))


def apply_mask(grads, mask):
    return jax.tree_util.tree_map(lambda g, m: g * m, grads, mask)
