from repro.optim.optimizers import (Optimizer, adamw, sgd, trainable_mask,
                                    apply_mask)
from repro.optim.proximal import control_variate_grad, proximal_grad
from repro.optim.schedules import constant, cosine, inverse_sqrt

__all__ = ["Optimizer", "sgd", "adamw", "trainable_mask", "apply_mask",
           "proximal_grad", "control_variate_grad", "constant", "cosine",
           "inverse_sqrt"]
