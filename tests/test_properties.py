"""Property-based tests (hypothesis) on the system's invariants."""
import math

import numpy as np
import pytest

# optional dependency: without the skip, the bare import aborts the whole
# suite at collection under ``pytest -x``
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import convergence
from repro.core.fedasync import ServerState, server_receive, staleness_fn
from repro.data import dirichlet_partition, iid_partition
from repro.kernels import ref
from repro.models.moe import capacity
from repro.types import FedConfig, MoEConfig

F = st.floats(min_value=-5, max_value=5, allow_nan=False,
              allow_infinity=False)


@given(a=st.floats(0.0, 2.0), x=st.integers(0, 1000))
def test_staleness_in_unit_interval(a, x):
    v = float(staleness_fn(a)(x))
    assert 0.0 < v <= 1.0
    assert v <= float(staleness_fn(a)(max(x - 1, 0)))


@given(beta=st.floats(0.05, 0.95), stale=st.integers(0, 50),
       w0=F, wn=F)
@settings(max_examples=30, deadline=None)
def test_mixing_is_convex_combination(beta, stale, w0, wn):
    """w_t always lies between w_{t-1} and w_new (elementwise)."""
    fed = FedConfig(mixing_beta=beta, staleness_a=0.5, max_staleness=100)
    state = ServerState(params={"w": jnp.asarray([w0])}, t=stale)
    out = server_receive(state, {"w": jnp.asarray([wn])}, tau=0, fed=fed)
    v = float(out.params["w"][0])
    lo, hi = min(w0, wn), max(w0, wn)
    assert lo - 1e-5 <= v <= hi + 1e-5
    # staleness moves the result toward the old value
    fresh = server_receive(ServerState(params={"w": jnp.asarray([w0])}, t=0),
                           {"w": jnp.asarray([wn])}, tau=0, fed=fed)
    assert abs(v - w0) <= abs(float(fresh.params["w"][0]) - w0) + 1e-6


@given(T=st.integers(1, 10000), E=st.integers(1, 64),
       k=st.integers(1, 4), cf=st.floats(1.0, 2.0))
def test_capacity_bounds(T, E, k, cf):
    moe = MoEConfig(num_experts=E, top_k=min(k, E), capacity_factor=cf)
    C = capacity(T, moe)
    assert C >= 1
    assert C * E >= T * moe.top_k          # total slots >= total assignments


@given(n=st.integers(1, 200), c=st.integers(1, 8))
def test_iid_partition_complete_and_disjoint(n, c):
    parts = iid_partition(n, min(c, n), seed=0)
    cat = np.concatenate(parts) if parts else np.array([])
    assert len(cat) == n
    assert len(np.unique(cat)) == n


@given(alpha=st.floats(0.05, 10.0), c=st.integers(2, 6))
@settings(max_examples=20, deadline=None)
def test_dirichlet_partition_complete(alpha, c):
    labels = np.repeat(np.arange(5), 30)
    parts = dirichlet_partition(labels, c, alpha=alpha, seed=1)
    cat = np.concatenate([p for p in parts if len(p)])
    assert len(cat) == len(labels)
    assert len(np.unique(cat)) == len(labels)


@given(rows=st.integers(1, 12), vocab=st.integers(2, 300),
       alpha=st.floats(0.0, 1.0), seed=st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_kd_loss_nonnegative_and_zero_at_match(rows, vocab, alpha, seed):
    rng = np.random.default_rng(seed)
    s = jnp.asarray(rng.standard_normal((rows, vocab)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, vocab, rows), jnp.int32)
    loss = ref.kd_loss_ref(s, s, lab, alpha)
    # teacher == student -> KD term zero; CE >= 0
    assert float(jnp.min(loss)) >= -1e-4
    pure_mse = ref.kd_loss_ref(s, s, lab, 0.0)
    np.testing.assert_allclose(np.asarray(pure_mse), 0.0, atol=1e-5)


@given(rows=st.integers(1, 10), vocab=st.integers(2, 200),
       alpha=st.floats(0.0, 1.0), temperature=st.floats(0.1, 10.0),
       seed=st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_kd_loss_convex_in_alpha(rows, vocab, alpha, temperature, seed):
    """L(α) is the exact convex combination α·L(1) + (1-α)·L(0) per row —
    the α knob interpolates the CE and KD terms, nothing else — and the
    fused kernel agrees with the oracle along the whole segment."""
    from repro.kernels.kd_loss import kd_loss_pallas
    rng = np.random.default_rng(seed)
    s = jnp.asarray(rng.standard_normal((rows, vocab)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((rows, vocab)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, vocab, rows), jnp.int32)
    l0 = ref.kd_loss_ref(s, t, lab, 0.0, temperature=temperature)
    l1 = ref.kd_loss_ref(s, t, lab, 1.0, temperature=temperature)
    la = ref.kd_loss_ref(s, t, lab, alpha, temperature=temperature)
    want = alpha * l1 + (1 - alpha) * l0
    scale = max(1.0, float(jnp.max(jnp.abs(want))))
    np.testing.assert_allclose(np.asarray(la), np.asarray(want),
                               rtol=1e-5, atol=1e-5 * scale)
    lk = kd_loss_pallas(s, t, lab, alpha, temperature=temperature,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(lk), np.asarray(la),
                               rtol=1e-5, atol=1e-5 * scale)


@given(rows=st.integers(1, 8), vocab=st.integers(2, 128),
       alpha=st.floats(0.0, 1.0), shift=st.floats(-30.0, 30.0),
       seed=st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_kd_loss_invariant_to_logit_shift(rows, vocab, alpha, shift, seed):
    """Adding the same constant to student AND teacher logits changes
    nothing: softmax-CE is shift-invariant and the MSE term sees only
    s - t. Holds for the oracle and the fused kernel."""
    from repro.kernels.kd_loss import kd_loss_pallas
    rng = np.random.default_rng(seed)
    s = jnp.asarray(rng.standard_normal((rows, vocab)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((rows, vocab)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, vocab, rows), jnp.int32)
    base = ref.kd_loss_ref(s, t, lab, alpha)
    shifted = ref.kd_loss_ref(s + shift, t + shift, lab, alpha)
    scale = max(1.0, float(jnp.max(jnp.abs(base))))
    np.testing.assert_allclose(np.asarray(shifted), np.asarray(base),
                               rtol=1e-4, atol=1e-4 * scale)
    k_shift = kd_loss_pallas(s + shift, t + shift, lab, alpha,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(k_shift), np.asarray(base),
                               rtol=1e-4, atol=1e-4 * scale)


@given(log10_scale=st.floats(-3.0, 3.0), alpha=st.floats(0.0, 1.0),
       temperature=st.floats(0.5, 4.0), seed=st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_kd_loss_rows_grad_finite_across_scales(log10_scale, alpha,
                                                temperature, seed):
    """The kernel's analytic backward stays finite from 1e-3x to 1e3x
    logit magnitudes (the training engine clips by global norm, but the
    raw gradients must never be NaN/Inf to begin with)."""
    from repro.kernels.kd_loss import kd_loss_rows
    rng = np.random.default_rng(seed)
    rows, vocab = 6, 96
    mag = 10.0 ** log10_scale
    s = jnp.asarray(rng.standard_normal((rows, vocab)) * mag, jnp.float32)
    t = jnp.asarray(rng.standard_normal((rows, vocab)) * mag, jnp.float32)
    lab = jnp.asarray(rng.integers(0, vocab, rows), jnp.int32)

    def total(sp, tp):
        return jnp.sum(kd_loss_rows(sp, tp, lab, alpha,
                                    temperature=temperature))

    ds, dt = jax.grad(total, argnums=(0, 1))(s, t)
    assert np.isfinite(np.asarray(ds)).all()
    assert np.isfinite(np.asarray(dt)).all()


@given(E=st.integers(1, 10**6), beta=st.floats(0.05, 0.95),
       K=st.integers(1, 32), lam=st.floats(1.0, 8.0))
@settings(max_examples=50, deadline=None)
def test_bound_positive_and_asymptotic_dominates(E, beta, K, lam):
    b = convergence.BoundInputs(
        E=E, beta=beta, eta=1.0 / math.sqrt(E), eps=1.0, K=K, lam=lam,
        H_min=1, F0_minus_FE=1.0)
    terms = convergence.bound_terms(b)
    assert all(v >= 0 for v in terms.values())
    assert convergence.bound(b) >= convergence.asymptotic_bound(b) * 0.99


@given(S=st.sampled_from([32, 64, 128]), w=st.integers(1, 128),
       seed=st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_swa_rows_are_probability_weighted(S, w, seed):
    """Each attention output row is a convex combination of values."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, S, 8)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, S, 8)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, S, 8)), jnp.float32)
    out = ref.swa_attention_ref(q, k, v, min(w, S))
    vmin, vmax = float(jnp.min(v)), float(jnp.max(v))
    assert float(jnp.min(out)) >= vmin - 1e-4
    assert float(jnp.max(out)) <= vmax + 1e-4


@given(W=st.integers(1, 24), pos=st.integers(0, 60),
       window=st.integers(0, 30), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_ring_decode_kernel_matches_einsum(W, pos, window, seed):
    """Fused ring decode attend == the einsum oracle for arbitrary
    (ring size, position, window) — including W = 1, odd windows, pos < W
    (partially written rings) and window 0 (full attention)."""
    from repro.kernels.swa_attention import ring_decode_attend_pallas
    from repro.models.attention import gqa_attention
    r = np.random.default_rng(seed)
    B, KV, G, D = 2, 2, 2, 8
    q = jnp.asarray(r.standard_normal((B, KV, G, D)) * 0.4, jnp.float32)
    k = jnp.asarray(r.standard_normal((B, W, KV, D)) * 0.4, jnp.float32)
    v = jnp.asarray(r.standard_normal((B, W, KV, D)), jnp.float32)
    got = ring_decode_attend_pallas(q, k, v, jnp.int32(pos),
                                    jnp.int32(window), interpret=True)
    k_pos = pos - jnp.mod(pos - jnp.arange(W), W)
    want = gqa_attention(q.reshape(B, 1, KV * G, D), k, v,
                         window=jnp.int32(window), causal=True,
                         q_offset=pos, k_positions=k_pos, q_chunk=1
                         ).reshape(B, KV, G, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@given(log2_ext=st.integers(0, 6), rel_pos=st.floats(0.0, 1.0),
       window=st.integers(0, 40), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_extent_decode_kernel_matches_einsum(log2_ext, rel_pos, window,
                                             seed):
    """Fused ladder-extent decode attend == the einsum slice + k_len-mask
    oracle at every pow-2 rung and any in-rung position."""
    from repro.kernels.swa_attention import extent_decode_attend_pallas
    from repro.models.attention import gqa_attention
    r = np.random.default_rng(seed)
    B, KV, G, D, S_max = 2, 2, 2, 8, 64
    k_ext = 2 ** log2_ext
    pos = min(int(rel_pos * (k_ext - 1)), k_ext - 1) if k_ext > 1 else 0
    q = jnp.asarray(r.standard_normal((B, KV, G, D)) * 0.4, jnp.float32)
    k = jnp.asarray(r.standard_normal((B, S_max, KV, D)) * 0.4, jnp.float32)
    v = jnp.asarray(r.standard_normal((B, S_max, KV, D)), jnp.float32)
    got = extent_decode_attend_pallas(q, k, v, jnp.int32(pos),
                                      jnp.int32(window), k_ext,
                                      interpret=True)
    want = gqa_attention(q.reshape(B, 1, KV * G, D),
                         k[:, :k_ext], v[:, :k_ext],
                         window=jnp.int32(window), causal=True,
                         q_offset=pos, k_len=pos + 1, q_chunk=1
                         ).reshape(B, KV, G, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@given(H=st.integers(1, 4), P=st.integers(1, 16), N=st.integers(1, 16),
       n_pad=st.integers(0, 2), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_ssd_decode_kernel_matches_einsum(H, P, N, n_pad, seed):
    """Fused SSD decode step == the einsum recurrence block; rows with
    dt = 0 (ladder pad steps) leave the state bit-identical."""
    from repro.kernels.ssd_scan import ssd_decode_step_pallas
    r = np.random.default_rng(seed)
    B = 3
    xh = jnp.asarray(r.standard_normal((B, H, P)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(r.standard_normal((B, H)),
                                     jnp.float32))
    pad_rows = list(range(min(n_pad, B)))
    for row in pad_rows:
        dt = dt.at[row].set(0.0)
    A = -jnp.exp(jnp.asarray(r.standard_normal(H) * 0.3, jnp.float32))
    Bm = jnp.asarray(r.standard_normal((B, N)) * 0.5, jnp.float32)
    Cm = jnp.asarray(r.standard_normal((B, N)) * 0.5, jnp.float32)
    st_in = jnp.asarray(r.standard_normal((B, H, P, N)), jnp.float32)
    dA = jnp.exp(dt * A[None, :])
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bm)
    st_want = st_in * dA[..., None, None] + upd
    y_want = jnp.einsum("bhpn,bn->bhp", st_want, Cm)
    y_got, st_got = ssd_decode_step_pallas(xh, dt, A, Bm, Cm, st_in,
                                           interpret=True)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_got), np.asarray(st_want),
                               rtol=1e-5, atol=1e-5)
    for row in pad_rows:
        assert bool(jnp.all(st_got[row] == st_in[row]))


# ---------------------------------------------------------------------------
# Wire codec (core/compression.py): the int8 / packed-int4 delta quantizer
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([4, 8]),
       size=st.integers(1, 33), scale=st.floats(1e-4, 10.0))
@settings(max_examples=40, deadline=None)
def test_quantize_delta_error_bound(seed, bits, size, scale):
    """Symmetric quantization error is bounded by scale/2 per element,
    for both wire widths (int4's [-7, 7] range keeps the bound exact)."""
    from repro.core import compression
    r = np.random.default_rng(seed)
    w = {"a": jnp.asarray(r.standard_normal(size) * scale, jnp.float32),
         "b": jnp.asarray(r.standard_normal((3, size)) * scale,
                          jnp.float32)}
    anchor = jax.tree_util.tree_map(jnp.zeros_like, w)
    upd = compression.quantize_delta(w, anchor, bits)
    assert upd.bits == bits
    deq = compression.dequantize_delta(upd, anchor)
    for wl, dl, s in zip(jax.tree_util.tree_leaves(w),
                         jax.tree_util.tree_leaves(deq),
                         jax.tree_util.tree_leaves(upd.scale)):
        err = np.max(np.abs(np.asarray(wl) - np.asarray(dl)))
        assert err <= float(s) / 2 + 1e-7


@given(size=st.integers(1, 64), bits=st.sampled_from([4, 8]))
@settings(max_examples=20, deadline=None)
def test_quantize_delta_zero_delta_exact(size, bits):
    """An all-zero delta survives the roundtrip exactly: q is all zeros
    and the reconstruction equals the anchor bit-for-bit."""
    from repro.core import compression
    w = {"x": jnp.linspace(-1.0, 1.0, size, dtype=jnp.float32)}
    out, upd = compression.roundtrip(w, w, bits)
    assert not np.asarray(upd.q["x"]).any()
    assert (np.asarray(out["x"]) == np.asarray(w["x"])).all()


@given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([4, 8]))
@settings(max_examples=20, deadline=None)
def test_quantize_delta_preserves_bf16_dtype(seed, bits):
    """bf16 anchors reconstruct to bf16 — the codec must not leak f32
    leaves into a mixed-precision model."""
    from repro.core import compression
    r = np.random.default_rng(seed)
    anchor = {"w": jnp.asarray(r.standard_normal(17), jnp.bfloat16)}
    w = {"w": anchor["w"] + jnp.asarray(0.25, jnp.bfloat16)}
    out, _ = compression.roundtrip(w, anchor, bits)
    assert out["w"].dtype == jnp.bfloat16


@given(seed=st.integers(0, 2**31 - 1), size=st.integers(1, 65))
@settings(max_examples=40, deadline=None)
def test_pack_int4_roundtrip(seed, size):
    """pack/unpack is the identity on int4-range values, including odd
    tails, and the packed payload is the accounted (size+1)//2 bytes."""
    from repro.core import compression
    r = np.random.default_rng(seed)
    q = r.integers(-7, 8, size=size).astype(np.int8)
    packed = compression.pack_int4(q)
    assert packed.nbytes == compression.packed_nbytes(size, 4)
    back = compression.unpack_int4(packed, size)
    assert (back == q).all()


@given(size=st.integers(1, 40), scale=st.floats(1e-3, 3.0))
@settings(max_examples=20, deadline=None)
def test_int4_wire_half_of_int8(size, scale):
    """Accounting: int4 payload bytes are (size+1)//2 per leaf, int8's
    are size; both add 4 bytes/leaf for the f32 scale."""
    from repro.core import compression
    w = {"x": jnp.full((size,), scale, jnp.float32)}
    a = {"x": jnp.zeros((size,), jnp.float32)}
    u8 = compression.quantize_delta(w, a, 8)
    u4 = compression.quantize_delta(w, a, 4)
    assert u8.wire_bytes == size + 4
    assert u4.wire_bytes == (size + 1) // 2 + 4


def test_quantize_delta_rejects_bad_bits():
    from repro.core import compression
    w = {"x": jnp.ones((3,), jnp.float32)}
    with pytest.raises(ValueError, match="wire width"):
        compression.quantize_delta(w, w, bits=3)
