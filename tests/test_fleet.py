"""Streaming-fleet layer (core/fleet.py): the million-client claims.

Property tests pinning the PR's contracts:
  * a streamed ``FleetSpec`` fleet is bit-identical to the same fleet
    fully materialized (sampling, H^k assignment, and shards are pure
    functions of (spec, k));
  * the hierarchical edge-aggregator round equals the flat psum weighted
    average (exact on a single-shard mesh, float32-close under real
    sharding) for ragged H^k counts and zero-weight padding clients;
  * resident state is O(sampled/in-flight), not O(population), at a
    10^6-client population;
  * the deprecation shim keeps the legacy parallel ``fleet``/
    ``client_data`` signature working (with a warning) and equal to the
    ``Fleet`` object path;
  * every ``engine=`` string resolves through the one validated
    ``EngineSpec`` definition;
  * ``Scheduler.pop_window`` policy="skip" admits a fresher later event
    where the legacy "stop" oracle ended the group.
"""
import dataclasses
import warnings

import numpy as np
import pytest

import jax

from repro.configs import RESNET18
from repro.core import fed_engine, fedavg, simulator
from repro.core.fleet import (ASYNC_ENGINES, SYNC_ENGINES, EngineSpec,
                              Fleet, FleetSpec, JETSON_FLEET_HMDB51)
from repro.core.simulator import Scheduler
from repro.data import BatchLoader, SyntheticActionDataset, iid_partition
from repro.data.partition import iid_shard
from repro.models import registry
from repro.types import FedConfig


def tree_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def tree_allclose(a, b, rtol=1e-5, atol=1e-5):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


@pytest.fixture(scope="module")
def tiny():
    cfg = RESNET18.reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticActionDataset(num_classes=8, samples_per_class=8, seed=1)
    return cfg, params, ds


def small_spec(ds, population=4, partition="iid"):
    return FleetSpec(population=population, profiles=JETSON_FLEET_HMDB51,
                     dataset=ds, batch_size=4, steps=4, seed=3,
                     partition=partition)


# ---------------------------------------------------------------------------
# FleetSpec determinism / iid_shard
# ---------------------------------------------------------------------------

def test_iid_shard_matches_iid_partition():
    parts = iid_partition(37, 5, seed=9)
    for k in range(5):
        np.testing.assert_array_equal(iid_shard(37, 5, k, seed=9),
                                      np.sort(parts[k]))
    with pytest.raises(ValueError):
        iid_shard(37, 5, 5)


def test_spec_is_deterministic_and_validated(tiny):
    _, _, ds = tiny
    spec = small_spec(ds, population=100)
    ks = [0, 1, 57, 99]
    assert [spec.profile_index(k) for k in ks] == \
           [spec.profile_index(k) for k in ks]
    fed = FedConfig(num_clients=100, local_iters_min=1, local_iters_max=4)
    for k in ks:
        h = spec.iters(k, fed)
        assert fed.local_iters_min <= h <= fed.local_iters_max
    with pytest.raises(ValueError):
        small_spec(ds, population=0)
    with pytest.raises(ValueError):
        dataclasses.replace(spec, partition="dirichlet")


def test_fleet_sample_exact_and_rejection():
    f = Fleet.from_spec(FleetSpec(
        population=10**6, profiles=JETSON_FLEET_HMDB51,
        dataset=SyntheticActionDataset(num_classes=4, samples_per_class=4),
        partition="shared"))
    rng = np.random.default_rng(0)
    s = f.sample(rng, 64, exclude=range(32))
    assert len(s) == len(set(s.tolist())) == 64
    assert not set(s.tolist()) & set(range(32))
    # small population takes the exact rng.choice path
    g = Fleet.from_spec(small_spec(
        SyntheticActionDataset(num_classes=4, samples_per_class=4),
        population=8, partition="shared"))
    s2 = g.sample(np.random.default_rng(0), 8)
    assert sorted(s2.tolist()) == list(range(8))


# ---------------------------------------------------------------------------
# Streamed == materialized (the tentpole's bit-identity contract)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_streamed_equals_materialized_sync(tiny):
    cfg, params, ds = tiny
    fed = FedConfig(num_clients=4, global_epochs=8, local_iters_min=1,
                    local_iters_max=2, lr=0.05, clients_per_round=2, seed=5)
    spec = small_spec(ds)
    ra = simulator.run_sync(params, cfg, fed, Fleet.from_spec(spec))
    rb = simulator.run_sync(params, cfg, fed,
                            Fleet.from_spec(spec).materialize())
    tree_equal(ra.params, rb.params)
    assert ra.history == rb.history


@pytest.mark.slow
def test_streamed_equals_materialized_async(tiny):
    cfg, params, ds = tiny
    fed = FedConfig(num_clients=4, global_epochs=8, local_iters_min=1,
                    local_iters_max=2, lr=0.05, clients_per_round=2, seed=5)
    spec = small_spec(ds)
    ra = simulator.run_async(params, cfg, fed, Fleet.from_spec(spec))
    rb = simulator.run_async(params, cfg, fed,
                             Fleet.from_spec(spec).materialize())
    tree_equal(ra.params, rb.params)
    assert ra.staleness_hist == rb.staleness_hist


# ---------------------------------------------------------------------------
# Hierarchical aggregation == flat weighted average
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_hierarchical_equals_flat_ragged_and_zero_weight(tiny):
    """Σ_e Σ_{k∈e} w_k·θ_k = Σ_k w_k·θ_k for ragged H^k counts plus a
    zero-weight client, on whatever mesh this host factors into."""
    cfg, params, ds = tiny
    fed = FedConfig(num_clients=5, local_iters_min=1, local_iters_max=3,
                    lr=0.05)
    # ragged counts 3,1,2,3,1 + zero weight on client 4
    counts = [3, 1, 2, 3, 1]
    data = [list(ds.batches(4, counts[k], seed=k)) for k in range(5)]
    sizes = [32, 8, 16, 32, 0]
    g_flat, l_flat = fedavg.fedavg_round(params, data, cfg, fed,
                                         engine="scan", data_sizes=sizes)
    data = [list(ds.batches(4, counts[k], seed=k)) for k in range(5)]
    g_hier, l_hier = fedavg.fedavg_round(params, data, cfg, fed,
                                         engine="hier", data_sizes=sizes)
    data = [list(ds.batches(4, counts[k], seed=k)) for k in range(5)]
    g_shard, _ = fedavg.fedavg_round(params, data, cfg, fed,
                                     engine="shard", data_sizes=sizes)
    if len(jax.devices()) == 1:
        tree_equal(g_flat, g_hier)      # one shard: psum is the identity
        tree_equal(g_shard, g_hier)
        for a, b in zip(l_flat, l_hier):
            np.testing.assert_array_equal(a, b)
    else:
        # real sharding: XLA picks reduction/fusion order per mesh —
        # float32-close, same tolerance as the existing shard-vs-loop
        # engine parity test
        tree_allclose(g_flat, g_hier, rtol=1e-3, atol=1e-4)
        tree_allclose(g_shard, g_hier, rtol=1e-3, atol=1e-4)
        for a, b in zip(l_flat, l_hier):
            np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


def test_hierarchical_mesh_validation():
    from repro.launch.mesh import make_fleet_mesh
    cfg = RESNET18.reduced()
    fed = FedConfig(num_clients=4)
    flat = make_fleet_mesh()
    with pytest.raises(ValueError):
        fed_engine.make_hierarchical_sync_round(cfg, fed, mesh=flat)
    n = len(jax.devices())
    with pytest.raises(ValueError):
        make_fleet_mesh(n, edges=n + 1)
    mesh = make_fleet_mesh(edges=0)
    assert set(mesh.axis_names) == {"edge", "clients"}


# ---------------------------------------------------------------------------
# Million-client resident state
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_million_client_sync_resident_is_o_sampled(tiny):
    cfg, params, ds = tiny
    fed = FedConfig(num_clients=10**6, global_epochs=8, local_iters_min=1,
                    local_iters_max=2, lr=0.05, clients_per_round=4)
    fleet = Fleet.from_spec(FleetSpec(
        population=10**6, profiles=JETSON_FLEET_HMDB51, dataset=ds,
        batch_size=4, steps=4, partition="shared"))
    res = simulator.run_sync(params, cfg, fed, fleet)
    assert len(res.history) == 2            # 8 epochs / 4 per round
    assert fleet.max_resident <= fed.clients_per_round
    assert fleet.resident == 0              # released after each round


@pytest.mark.slow
def test_million_client_async_resident_is_o_inflight(tiny):
    cfg, params, ds = tiny
    fed = FedConfig(num_clients=10**6, global_epochs=10, local_iters_min=1,
                    local_iters_max=2, lr=0.05, clients_per_round=4)
    fleet = Fleet.from_spec(FleetSpec(
        population=10**6, profiles=JETSON_FLEET_HMDB51, dataset=ds,
        batch_size=4, steps=4, partition="shared"))
    res = simulator.run_async(params, cfg, fed, fleet)
    assert len(res.history) == fed.global_epochs
    assert fleet.max_resident <= fed.clients_per_round
    assert res.max_inflight <= fed.clients_per_round


# ---------------------------------------------------------------------------
# Satellite: deprecation shim for the legacy parallel-args signature
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_legacy_signature_warns_and_matches_fleet_object(tiny):
    cfg, params, ds = tiny
    fed = FedConfig(num_clients=4, global_epochs=6, local_iters_min=1,
                    local_iters_max=2, lr=0.05)
    parts = iid_partition(len(ds), 4)

    def loaders():
        return [BatchLoader(ds, 4, steps=4, seed=k, indices=parts[k])
                for k in range(4)]

    with pytest.warns(DeprecationWarning, match="Fleet.from_lists"):
        r_old = simulator.run_sync(params, cfg, fed, JETSON_FLEET_HMDB51,
                                   loaders())
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        r_new = simulator.run_sync(
            params, cfg, fed,
            Fleet.from_lists(JETSON_FLEET_HMDB51, loaders()))
    tree_equal(r_old.params, r_new.params)


def test_resolve_validation(tiny):
    cfg, params, ds = tiny
    fed = FedConfig(num_clients=4)
    f = Fleet.from_spec(small_spec(ds))
    with pytest.raises(ValueError):      # client_data alongside a Fleet
        Fleet.resolve(f, [lambda: []], fed)
    with pytest.raises(ValueError):      # population != num_clients
        Fleet.resolve(Fleet.from_spec(small_spec(ds, population=8)),
                      None, fed)
    with pytest.raises(ValueError):      # oversubscribed sampling
        Fleet.resolve(f, None,
                      dataclasses.replace(fed, clients_per_round=9))
    with pytest.raises(ValueError):      # ragged lists
        Fleet.from_lists(JETSON_FLEET_HMDB51[:3], [lambda: []] * 4)


# ---------------------------------------------------------------------------
# Satellite: one validated EngineSpec
# ---------------------------------------------------------------------------

def test_engine_spec_from_str():
    assert EngineSpec.from_str("scan") is EngineSpec.SCAN
    assert EngineSpec.from_str(EngineSpec.HIER) is EngineSpec.HIER
    with pytest.raises(ValueError, match="scan.*loop.*shard.*hier"):
        EngineSpec.from_str("turbo")
    with pytest.raises(ValueError, match="not supported here"):
        EngineSpec.from_str("shard", allowed=ASYNC_ENGINES)
    assert set(SYNC_ENGINES) == set(EngineSpec)


def test_simulator_rejects_invalid_engines(tiny):
    cfg, params, ds = tiny
    fed = FedConfig(num_clients=4, global_epochs=4)
    fleet = Fleet.from_spec(small_spec(ds, partition="shared"))
    with pytest.raises(ValueError, match="one of"):
        simulator.run_sync(params, cfg, fed, fleet, engine="bogus")
    with pytest.raises(ValueError, match="not supported here"):
        simulator.run_async(params, cfg, fed, fleet, engine="hier")
    with pytest.raises(ValueError, match="one of"):
        fedavg.fedavg_round(params, [], cfg, fed, engine="bogus")


# ---------------------------------------------------------------------------
# Satellite: pop_window skip-vs-stop group composition
# ---------------------------------------------------------------------------

def _push(sched, ft, client, tau):
    sched.push(ft, client, {"w": np.zeros(1)}, tau, 0.0)


def test_pop_window_skip_admits_fresher_later_event():
    """A too-stale event no longer ends the group: the fresher event
    behind it still joins, and the stale one survives for a later group
    (where Algorithm 1's clamp applies)."""
    # group leader at t=10; event B too stale at position 1; C is fresh
    events = [(1.0, 0, 10), (1.5, 1, 2), (2.0, 2, 10)]
    t, K = 10, 8

    skip = Scheduler(window=5.0, policy="skip")
    for ft, k, tau in events:
        _push(skip, ft, k, tau)
    group = skip.pop_window(t, K, budget=10)
    assert [g[1] for g in group] == [0, 2]   # B skipped, C admitted
    assert len(skip) == 1                     # B still queued
    assert skip.pop_window(t + 2, K, budget=10)[0][1] == 1

    stop = Scheduler(window=5.0, policy="stop")
    for ft, k, tau in events:
        _push(stop, ft, k, tau)
    group = stop.pop_window(t, K, budget=10)
    assert [g[1] for g in group] == [0]       # legacy: B ended the group
    # B pushed back, C never popped — both still queued
    assert len(stop) == 2
    with pytest.raises(ValueError):
        Scheduler(policy="drop")


def test_pop_window_group_staleness_bound_still_holds():
    sched = Scheduler(window=100.0, policy="skip")
    rng = np.random.default_rng(0)
    for i in range(32):
        _push(sched, float(rng.uniform(0, 50)), i, int(rng.integers(0, 20)))
    t, K = 25, 6
    while len(sched):
        group = sched.pop_window(t, K, budget=8)
        assert 1 <= len(group) <= 8
        for i, (_, _, _, tau, _) in enumerate(group):
            assert (t + i) - tau <= K or i == 0   # leader clamps instead
        t += len(group)
