"""Runtime guard rails: the PR-2 and PR-5 compile/transfer claims as
executable invariants.

``guard_rails()`` makes every *implicit* host->device transfer an error
(and checks for tracer leaks); ``compile_budget(cache, n)`` pins the
``JitCache`` compile delta. Together they assert the steady state of the
two compiled hot paths: the padded fed round re-runs new H^k draws with
zero new programs and zero hidden transfers, and the serving ladder
replays a whole stream without compiling or syncing implicitly.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import fed_engine
from repro.core.serving import ContinuousBatcher
from repro.data import SyntheticLMDataset, stack_batches
from repro.models import registry
from repro.types import FedConfig, ModelConfig

pytestmark = pytest.mark.guard_rails

TINY = ModelConfig(name="guard-test-tiny", family="dense", num_layers=1,
                   d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                   vocab_size=64)


def test_padded_round_one_compile_no_implicit_transfers(guard_rails,
                                                        compile_budget):
    """PR-2 invariant: H^k is traced, not a compile key — after one
    warm-up, new H vectors run with ZERO new programs and zero implicit
    host->device transfers (all inputs are device_put up front)."""
    fed = FedConfig(num_clients=3, global_epochs=2, local_iters_min=1,
                    local_iters_max=3, lr=0.01)
    ds = SyntheticLMDataset(vocab=TINY.vocab_size, seq_len=8, seed=0)
    params = registry.init_params(jax.random.PRNGKey(0), TINY)
    run = fed_engine.ClientRun(TINY, fed)   # private: isolate cache counts
    mask = jax.tree_util.tree_map(
        lambda _: jnp.asarray(1.0, jnp.float32), params)

    def padded(Hs, seed0):
        blists = [list(ds.batches(2, h, seed=seed0 + i))
                  for i, h in enumerate(Hs)]
        stacked, lens = fed_engine.pad_client_batches(
            [stack_batches(iter(b)) for b in blists],
            H_max=fed.local_iters_max)
        return (jax.device_put(jax.tree_util.tree_map(jnp.asarray,
                                                      stacked)),
                jnp.asarray(lens, jnp.int32))

    stacked, iters = padded([3, 1, 2], 10)
    with compile_budget(run, 1, exact=True):   # warm-up traces the program
        run.run_batch(params, stacked, iters=iters, mask=mask)

    for k, Hs in enumerate(([1, 2, 1], [2, 3, 3])):
        stacked, iters = padded(Hs, 40 + 10 * k)
        with guard_rails(), compile_budget(run, 0, exact=True):
            w_news, losses = run.run_batch(params, stacked, iters=iters,
                                           mask=mask)
        la = jax.device_get(losses)
        for j, h in enumerate(Hs):
            assert np.all(np.isfinite(la[j, :h]))
            assert np.all(np.isnan(la[j, h:]))
    assert run.num_compiled == 1


def test_scaffold_padded_round_steady_state(guard_rails, compile_budget):
    """PR-10 invariant: a stateful algorithm (SCAFFOLD) rides the SAME
    padded masked-scan contract — per-client control variates and the
    server variate are traced arguments, so after one warm-up a new H^k
    draw runs with ZERO new programs and zero implicit transfers."""
    from repro.core.algorithms import Scaffold
    fed = FedConfig(num_clients=3, global_epochs=2, local_iters_min=1,
                    local_iters_max=3, lr=0.01)
    ds = SyntheticLMDataset(vocab=TINY.vocab_size, seq_len=8, seed=0)
    params = registry.init_params(jax.random.PRNGKey(0), TINY)
    alg = Scaffold()
    run = fed_engine.ClientRun(TINY, fed, algorithm=alg)  # private cache
    mask = jax.tree_util.tree_map(
        lambda _: jnp.asarray(1.0, jnp.float32), params)
    ctx = jax.device_put(alg.ctx_for(params))
    states = jax.device_put(alg.stacked_states(params, range(3)))

    def padded(Hs, seed0):
        blists = [list(ds.batches(2, h, seed=seed0 + i))
                  for i, h in enumerate(Hs)]
        stacked, lens = fed_engine.pad_client_batches(
            [stack_batches(iter(b)) for b in blists],
            H_max=fed.local_iters_max)
        return (jax.device_put(jax.tree_util.tree_map(jnp.asarray,
                                                      stacked)),
                jnp.asarray(lens, jnp.int32))

    stacked, iters = padded([3, 1, 2], 10)
    with compile_budget(run, 1, exact=True):   # warm-up traces the program
        out = run.run_batch(params, stacked, iters=iters, mask=mask,
                            server_ctx=ctx, states=states)
    assert len(out) == 4                       # (w, states, msgs, losses)

    for k, Hs in enumerate(([1, 2, 1], [2, 3, 3])):
        stacked, iters = padded(Hs, 40 + 10 * k)
        with guard_rails(), compile_budget(run, 0, exact=True):
            _, new_states, _, losses = run.run_batch(
                params, stacked, iters=iters, mask=mask,
                server_ctx=ctx, states=states)
        la = jax.device_get(losses)
        for j, h in enumerate(Hs):
            assert np.all(np.isfinite(la[j, :h]))
            assert np.all(np.isnan(la[j, h:]))
        # the new variates are well-formed (the state output is real work,
        # not a passthrough)
        for leaf in jax.tree_util.tree_leaves(jax.device_get(new_states)):
            assert np.all(np.isfinite(leaf))
    assert run.num_compiled == 1


def test_serving_ladder_steady_state_no_compiles(guard_rails,
                                                 compile_budget, rng):
    """PR-5 invariant: decode programs are bounded by the bucket ladder,
    and an identical second stream replays entirely warm — zero new
    programs, zero implicit transfers, bit-identical outputs."""
    cfg = get_config("hymba-1.5b").reduced()
    params = registry.init_params(jax.random.PRNGKey(8), cfg)
    lengths, max_new = (3, 9, 21), (20, 12, 30)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lengths]

    srv = ContinuousBatcher(params, cfg, max_slots=2, max_len=64,
                            min_bucket=4, decode_mode="ring")
    for p, m in zip(prompts, max_new):
        srv.submit(p, max_new=m)
    done = srv.run()
    assert 2 <= srv.decode_compiles <= len(srv.decode_buckets)

    for p, m in zip(prompts, max_new):
        srv.submit(p, max_new=m)
    with guard_rails(), compile_budget(srv._jits, 0, exact=True):
        done2 = srv.run()                  # cumulative completed list
    assert [r.out for r in done2[len(done):]] == [r.out for r in done]


def test_fused_decode_steady_state_no_compiles(guard_rails,
                                               compile_budget, rng):
    """PR-7 invariant: the fused-Pallas decode path obeys the same
    compile discipline as the einsum oracle — warm decode programs are
    bounded by the K-extent ladder, and a second identical stream runs
    with zero new programs and zero implicit host transfers (the kernels
    take traced pos/window operands, never compile keys or host syncs)."""
    cfg = get_config("hymba-1.5b").reduced()
    params = registry.init_params(jax.random.PRNGKey(8), cfg)
    lengths, max_new = (3, 9, 21), (20, 12, 30)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lengths]

    srv = ContinuousBatcher(params, cfg, max_slots=2, max_len=64,
                            min_bucket=4, decode_mode="ring",
                            decode_kernel="pallas")
    for p, m in zip(prompts, max_new):
        srv.submit(p, max_new=m)
    done = srv.run()
    assert 2 <= srv.decode_compiles <= len(srv.decode_buckets)

    for p, m in zip(prompts, max_new):
        srv.submit(p, max_new=m)
    with guard_rails(), compile_budget(srv._jits, 0, exact=True):
        done2 = srv.run()
    assert [r.out for r in done2[len(done):]] == [r.out for r in done]

    # the oracle kernel must produce the very same stream
    srv_e = ContinuousBatcher(params, cfg, max_slots=2, max_len=64,
                              min_bucket=4, decode_mode="ring",
                              decode_kernel="einsum")
    for p, m in zip(prompts, max_new):
        srv_e.submit(p, max_new=m)
    assert [r.out for r in srv_e.run()] == [r.out for r in done]


# ---------------------------------------------------------------------------
# PR-8: distillation as a compiled fleet workload
# ---------------------------------------------------------------------------

STUDENT = ModelConfig(name="guard-test-student", family="dense",
                      num_layers=1, d_model=16, num_heads=2, num_kv_heads=2,
                      d_ff=32, vocab_size=64)


def _device_stack(ds, batch, steps, seed):
    stacked = stack_batches(iter(ds.batches(batch, steps, seed=seed)))
    return jax.device_put(jax.tree_util.tree_map(jnp.asarray, stacked))


def test_distill_epoch_steady_state_no_compiles(guard_rails,
                                                compile_budget):
    """PR-8 invariant: a warm KD epoch (teacher fwd + student step per
    scan iteration, fused Pallas KD loss) is ONE program — fresh epochs at
    the same (H, batch) shape run with zero new compiles and zero
    implicit host->device transfers."""
    from repro.core.distill import DistillEngine
    from repro.data import SyntheticLMDataset
    from repro.types import DistillConfig

    dcfg = DistillConfig(lr=0.01, batch_size=2)
    ds = SyntheticLMDataset(vocab=TINY.vocab_size, seq_len=8, seed=0)
    engine = DistillEngine(TINY, STUDENT, dcfg)   # private: isolate counts
    t_params = registry.init_params(jax.random.PRNGKey(0), TINY)
    params = registry.init_params(jax.random.PRNGKey(1), STUDENT)
    opt = engine.opt.init(params)

    stacked = _device_stack(ds, 2, 3, seed=1)
    with compile_budget(engine, 1, exact=True):    # warm-up traces it
        params, opt, losses = engine.epoch(t_params, params, opt, stacked)

    for seed in (2, 3):
        stacked = _device_stack(ds, 2, 3, seed=seed)
        with guard_rails(), compile_budget(engine, 0, exact=True):
            params, opt, losses = engine.epoch(t_params, params, opt,
                                               stacked)
        assert np.all(np.isfinite(jax.device_get(losses)))
    assert engine.num_compiled == 1


def test_kd_to_finetune_handoff_no_recompile(guard_rails, compile_budget):
    """PR-8 invariant: the KD -> fine-tune handoff is pure data. The fed
    engine's round program is keyed on shapes only, so feeding it
    distilled student params instead of a scratch init triggers ZERO new
    compiles and zero implicit transfers."""
    from repro.core.distill import DistillEngine
    from repro.data import SyntheticLMDataset
    from repro.types import DistillConfig

    ds = SyntheticLMDataset(vocab=TINY.vocab_size, seq_len=8, seed=0)
    fed = FedConfig(num_clients=2, global_epochs=2, local_iters_min=2,
                    local_iters_max=2, lr=0.01)
    rnd = fed_engine.SyncRound(TINY, fed)    # private: isolate cache counts
    scratch = registry.init_params(jax.random.PRNGKey(0), TINY)
    mask = jax.tree_util.tree_map(
        lambda _: jnp.asarray(1.0, jnp.float32), scratch)
    weights = jnp.full((2,), 0.5, jnp.float32)

    def client_stack(seed0):
        stacks = [stack_batches(iter(ds.batches(2, 2, seed=seed0 + k)))
                  for k in range(2)]
        both = {k: np.stack([s[k] for s in stacks]) for k in stacks[0]}
        return jax.device_put(jax.tree_util.tree_map(jnp.asarray, both))

    stacks = client_stack(10)
    with compile_budget(rnd, 1, exact=True):       # warm the round program
        rnd(scratch, stacks, weights, mask=mask)

    # stage 1: distill a student of the SAME deployable arch (self-KD at
    # test scale), then hand its params to the warm round program
    dcfg = DistillConfig(lr=0.01, batch_size=2)
    engine = DistillEngine(TINY, TINY, dcfg)
    opt = engine.opt.init(scratch)
    distilled, _, _ = engine.epoch(
        registry.init_params(jax.random.PRNGKey(3), TINY),
        scratch, opt, _device_stack(ds, 2, 3, seed=5))

    stacks = client_stack(20)
    with guard_rails(), compile_budget(rnd, 0, exact=True):
        new_global, losses = rnd(distilled, stacks, weights, mask=mask)
    assert np.all(np.isfinite(jax.device_get(losses)))
    assert rnd.num_compiled == 1
