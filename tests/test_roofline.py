"""Loop-aware HLO analyzer: validated against programs with known costs."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.roofline.hlo import analyze_hlo, parse_hlo
from repro.roofline.analysis import HW, RooflineReport


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_trip_count_multiplies_flops():
    N, T = 256, 12
    a = jax.ShapeDtypeStruct((N, N), jnp.float32)

    def g(a, b):
        def body(x, _):
            return x @ b, None
        y, _ = jax.lax.scan(body, a, None, length=T)
        return y

    comp = _compile(g, a, a)
    c = analyze_hlo(comp.as_text())
    expected = T * 2 * N ** 3
    assert 0.9 * expected < c.flops < 1.3 * expected
    assert any(trip == T for _, trip in c.loops)


def test_single_matmul_flops_and_bytes():
    M, K, N = 128, 512, 256
    a = jax.ShapeDtypeStruct((M, K), jnp.float32)
    b = jax.ShapeDtypeStruct((K, N), jnp.float32)
    comp = _compile(lambda a, b: a @ b, a, b)
    c = analyze_hlo(comp.as_text())
    expected = 2 * M * K * N
    assert 0.95 * expected < c.flops < 1.2 * expected
    io_bytes = 4 * (M * K + K * N + M * N)
    assert c.bytes >= io_bytes * 0.9


def test_nested_scan_multiplies():
    N, T1, T2 = 64, 5, 7
    a = jax.ShapeDtypeStruct((N, N), jnp.float32)

    def g(a, b):
        def outer(x, _):
            def inner(y, _):
                return y @ b, None
            y, _ = jax.lax.scan(inner, x, None, length=T2)
            return y, None
        y, _ = jax.lax.scan(outer, a, None, length=T1)
        return y

    comp = _compile(g, a, a)
    c = analyze_hlo(comp.as_text())
    expected = T1 * T2 * 2 * N ** 3
    assert 0.9 * expected < c.flops < 1.4 * expected


def test_dus_counted_as_update_not_buffer():
    big = jax.ShapeDtypeStruct((4096, 4096), jnp.float32)   # 64 MiB
    small = jax.ShapeDtypeStruct((1, 4096), jnp.float32)    # 16 KiB

    def g(buf, upd):
        def body(b, i):
            return jax.lax.dynamic_update_slice(b, upd, (i, 0)), None
        out, _ = jax.lax.scan(body, buf, jnp.arange(100))
        return out

    comp = _compile(g, big, small)
    c = analyze_hlo(comp.as_text())
    # 100 iterations: if the full buffer were counted, bytes > 100*64MiB
    assert c.bytes < 50 * 64 * 2 ** 20


def test_report_terms_and_dominance():
    rep = RooflineReport(
        arch="x", shape="train_4k", mesh="pod", chips=256,
        flops_per_device=197e12, bytes_per_device=819e9 * 2,
        collective_bytes=50e9 * 0.5, collectives={"all-gather": 50e9 * 0.5},
        peak_memory_bytes=8e9, model_flops_global=197e12 * 256 * 0.25)
    assert rep.compute_s == pytest.approx(1.0)
    assert rep.memory_s == pytest.approx(2.0)
    assert rep.collective_s == pytest.approx(0.5)
    assert rep.dominant == "memory"
    assert rep.step_time_s == pytest.approx(2.0)
    assert rep.mfu == pytest.approx(0.125)
    d = rep.to_dict()
    assert d["dominant"] == "memory"


# ---------------------------------------------------------------------------
# Analytic decode-step byte models (PR 7): the fused Pallas kernels'
# CostEstimates must be THE model in analysis.py, and the fused model must
# be strictly cheaper than the einsum path it replaces.
# ---------------------------------------------------------------------------

from repro.roofline.analysis import (attend_decode_bytes, attend_decode_flops,
                                     ssd_decode_bytes, ssd_decode_flops)


def test_attend_decode_bytes_fused_below_einsum():
    for n_ctx in (1, 4, 64, 512):
        for kv, g in ((1, 1), (2, 4), (8, 1)):
            fused = attend_decode_bytes(n_ctx, kv, kv * g, 64)
            unfused = attend_decode_bytes(n_ctx, kv, kv * g, 64, fused=False)
            assert fused < unfused
            # the gap is exactly the scores+probs HBM round trips
            assert unfused - fused == 4 * (kv * g) * n_ctx * 4
    with pytest.raises(ValueError):
        attend_decode_bytes(0, 1, 1, 64)


def test_ssd_decode_bytes_fused_below_einsum():
    for h, p, n in ((1, 1, 1), (8, 64, 128), (3, 5, 7)):
        fused = ssd_decode_bytes(h, p, n)
        unfused = ssd_decode_bytes(h, p, n, fused=False)
        assert fused < unfused
        # the gap is exactly the materialized update tensor round trip
        assert unfused - fused == 2 * h * p * n * 4


def test_attend_kernel_cost_estimate_matches_model():
    """The CostEstimate the decode-attend kernels hand to XLA is the
    analysis.py fused model, per stream, not an ad-hoc recount."""
    pl = pytest.importorskip("jax.experimental.pallas")
    if not hasattr(pl, "CostEstimate"):
        pytest.skip("jax too old for pl.CostEstimate")
    from repro.kernels.swa_attention import _cost_kwargs
    B, n_ctx, kv, g, d = 3, 16, 2, 4, 8
    est = _cost_kwargs(B, n_ctx, kv, g, d, jnp.float32)["cost_estimate"]
    assert est.bytes_accessed == B * attend_decode_bytes(n_ctx, kv, kv * g, d)
    assert est.flops == B * attend_decode_flops(n_ctx, kv * g, d)


def test_ssd_kernel_cost_estimate_matches_model():
    pl = pytest.importorskip("jax.experimental.pallas")
    if not hasattr(pl, "CostEstimate"):
        pytest.skip("jax too old for pl.CostEstimate")
    from repro.kernels.ssd_scan import ssd_decode_step_pallas
    captured = {}
    orig = pl.pallas_call

    def spy(*args, **kw):
        captured.update(kw)
        return orig(*args, **kw)

    B, H, P, N = 2, 3, 4, 5
    f32 = jnp.float32
    args = (jnp.ones((B, H, P), f32), jnp.ones((B, H), f32),
            jnp.ones((H,), f32), jnp.ones((B, N), f32),
            jnp.ones((B, N), f32), jnp.ones((B, H, P, N), f32))
    import repro.kernels.ssd_scan as mod
    old = mod.pl.pallas_call
    mod.pl.pallas_call = spy
    try:
        ssd_decode_step_pallas(*args, interpret=True)
    finally:
        mod.pl.pallas_call = old
    est = captured["cost_estimate"]
    assert est.bytes_accessed == B * ssd_decode_bytes(H, P, N)
    assert est.flops == B * ssd_decode_flops(H, P, N)
