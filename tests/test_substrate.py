"""Optimizers, checkpointing, data pipeline, sharding rules."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.checkpoint import load_params, save_params
from repro.data import (BatchLoader, SyntheticActionDataset,
                        SyntheticLMDataset, dirichlet_partition,
                        iid_partition)
from repro.optim import adamw, apply_mask, sgd, trainable_mask
from repro.optim.schedules import cosine, inverse_sqrt


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def test_sgd_matches_manual():
    opt = sgd(0.1, momentum=0.9, weight_decay=0.01)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    st = opt.init(p)
    p1, st = opt.update(g, st, p)
    eff = 0.5 + 0.01 * np.asarray([1.0, -2.0])       # wd
    mom = eff                                         # m1 = g
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               np.asarray([1.0, -2.0]) - 0.1 * mom,
                               rtol=1e-6)
    p2, st = opt.update(g, st, p1)
    eff2 = 0.5 + 0.01 * np.asarray(p1["w"])
    mom2 = 0.9 * mom + eff2
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               np.asarray(p1["w"]) - 0.1 * mom2, rtol=1e-6)


def test_adamw_converges_quadratic():
    opt = adamw(0.1)
    p = {"w": jnp.zeros(3)}
    st = opt.init(p)
    target = jnp.asarray([1.0, -2.0, 0.5])
    for _ in range(300):
        g = {"w": p["w"] - target}
        p, st = opt.update(g, st, p)
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(target),
                               atol=1e-2)


def test_trainable_mask_last_layer():
    params = {"embed": jnp.ones((4, 2)), "layers": {"wq": jnp.ones((2, 2))},
              "final_norm": jnp.ones(2), "lm_head": jnp.ones((2, 4))}
    mask = trainable_mask(params, "last_layer")
    assert mask["lm_head"] == 1.0 and mask["final_norm"] == 1.0
    assert mask["layers"]["wq"] == 0.0 and mask["embed"] == 0.0
    g = apply_mask(params, mask)
    assert float(jnp.sum(g["layers"]["wq"])) == 0.0


def test_schedules():
    cs = cosine(1.0, total_steps=100, warmup=10)
    assert float(cs(0)) == 0.0
    assert float(cs(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(cs(100)) == pytest.approx(0.1, rel=1e-2)
    inv = inverse_sqrt(1.0, warmup=4)
    assert float(inv(16)) == 0.25


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, rng):
    params = {"a": jnp.asarray(rng.standard_normal((3, 4)), jnp.float32),
              "nested": {"b": jnp.arange(5, dtype=jnp.int32)},
              "lst": [jnp.ones(2), jnp.zeros((2, 2))]}
    path = os.path.join(tmp_path, "ck")
    save_params(params, path, extra={"step": 7})
    back = load_params(jax.tree_util.tree_map(jnp.zeros_like, params), path)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_server_state_roundtrip(tmp_path):
    from repro.checkpoint import load_server_state, save_server_state
    from repro.core.fedasync import ServerState
    st = ServerState(params={"w": jnp.ones(3)}, t=11, total_updates=42)
    path = os.path.join(tmp_path, "server")
    save_server_state(st, path)
    st2 = load_server_state({"w": jnp.zeros(3)}, path)
    assert st2.t == 11 and st2.total_updates == 42
    np.testing.assert_array_equal(np.asarray(st2.params["w"]), 1.0)


# ---------------------------------------------------------------------------
# Data
# ---------------------------------------------------------------------------

def test_action_dataset_deterministic():
    ds = SyntheticActionDataset(num_classes=4, samples_per_class=4, seed=7)
    b1 = next(ds.batches(4, 1, seed=1))
    b2 = next(SyntheticActionDataset(num_classes=4, samples_per_class=4,
                                     seed=7).batches(4, 1, seed=1))
    np.testing.assert_array_equal(b1["clips"], b2["clips"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_action_dataset_classes_distinguishable():
    """Same class twice is closer than two different classes (on average)."""
    ds = SyntheticActionDataset(num_classes=4, samples_per_class=4, seed=0,
                                noise=0.1)
    r = np.random.default_rng(0)
    same = np.mean([np.linalg.norm(ds.render(0, r) - ds.render(0, r))
                    for _ in range(5)])
    diff = np.mean([np.linalg.norm(ds.render(0, r) - ds.render(2, r))
                    for _ in range(5)])
    assert diff > same * 0.9


def test_lm_dataset_shapes():
    ds = SyntheticLMDataset(vocab=64, seq_len=16, seed=0)
    b = next(ds.batches(3, 1))
    assert b["tokens"].shape == (3, 16) and b["labels"].shape == (3, 16)
    assert b["tokens"].max() < 64
    # labels are next-token of tokens
    full = np.concatenate([b["tokens"][:, :1], b["labels"]], axis=1)
    np.testing.assert_array_equal(b["tokens"][:, 1:], full[:, 1:-1])


def test_partitions():
    parts = iid_partition(100, 4, seed=0)
    allidx = np.sort(np.concatenate(parts))
    np.testing.assert_array_equal(allidx, np.arange(100))
    labels = np.repeat(np.arange(10), 20)
    dparts = dirichlet_partition(labels, 4, alpha=0.1, seed=0)
    assert sum(len(p) for p in dparts) == 200
    # non-IID: at least one client has a skewed class histogram
    h = [np.bincount(labels[p], minlength=10) / max(len(p), 1)
         for p in dparts]
    assert max(hh.max() for hh in h) > 0.2


def test_batch_loader_restartable():
    ds = SyntheticLMDataset(vocab=32, seq_len=8, seed=0)
    loader = BatchLoader(ds, 2, steps=3, seed=5)
    n1 = sum(1 for _ in loader())
    n2 = sum(1 for _ in loader())
    assert n1 == n2 == 3


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

def _fake_mesh():
    """AbstractMesh-like stand-in for rule tests (no 256 devices needed)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh((16, 16), ("data", "model"))
    except TypeError:
        # jax <= 0.4.x signature: a tuple of (axis_name, size) pairs
        return AbstractMesh((("data", 16), ("model", 16)))


def test_param_specs_divisible():
    from repro.configs import get_config
    from repro.models import registry
    from repro.sharding import param_pspecs
    mesh = _fake_mesh()
    for arch in ("grok-1-314b", "hymba-1.5b", "mamba2-130m",
                 "seamless-m4t-large-v2"):
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda: registry.init_params(jax.random.PRNGKey(0), cfg))
        specs = param_pspecs(mesh, cfg, shapes)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        flat_l = jax.tree_util.tree_leaves(shapes)
        for spec, leaf in zip(flat_s, flat_l):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                size = 1
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    size *= dict(data=16, model=16)[a]
                assert dim % size == 0, (spec, leaf.shape)


def test_moe_expert_sharding_rule():
    from repro.configs import get_config
    from repro.models import registry
    from repro.sharding import param_pspecs
    mesh = _fake_mesh()
    l4 = get_config("llama4-scout-17b-a16e")     # 16 experts -> expert dim
    shapes = jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), l4))
    specs = param_pspecs(mesh, l4, shapes)
    assert tuple(specs["layers"]["moe"]["wi"])[1] == "model"
    gk = get_config("grok-1-314b")               # 8 experts -> tensor 2D
    shapes = jax.eval_shape(
        lambda: registry.init_params(jax.random.PRNGKey(0), gk))
    specs = param_pspecs(mesh, gk, shapes)
    si = tuple(specs["layers"]["moe"]["wi"])
    assert si[1] is None and "model" in si


def test_batch_specs_divisibility_guard():
    from repro.configs import get_config
    from repro.sharding import batch_pspecs
    from repro.types import ShapeConfig
    mesh = _fake_mesh()
    cfg = get_config("internlm2-20b")
    batch = {"tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32)}
    spec = batch_pspecs(mesh, cfg, batch)
    assert tuple(spec["tokens"])[0] == "data"
    odd = {"tokens": jax.ShapeDtypeStruct((3, 128), jnp.int32)}
    spec = batch_pspecs(mesh, cfg, odd)
    assert tuple(spec["tokens"])[0] is None
