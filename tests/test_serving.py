"""Continuous-batching server: parity with single-request generation."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.serving import ContinuousBatcher, generate_single
from repro.models import registry


@pytest.mark.parametrize("arch", ["h2o-danube-3-4b", "mamba2-130m",
                                  "hymba-1.5b"])
def test_continuous_batching_matches_single(arch, rng):
    """Greedy outputs under slot batching == running each request alone,
    despite different prompt lengths, admission times and retirements."""
    cfg = get_config(arch).reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 3, 7)]
    max_new = [6, 4, 8, 5]

    srv = ContinuousBatcher(params, cfg, max_slots=2, max_len=64)
    for p, m in zip(prompts, max_new):
        srv.submit(p, max_new=m)
    done = srv.run()
    assert len(done) == 4

    for req, p, m in zip(done, prompts, max_new):
        ref = generate_single(params, cfg, p, m, max_len=64)
        assert req.out == ref, (req.rid, req.out, ref)


def test_server_respects_slot_limit(rng):
    cfg = get_config("mamba2-130m").reduced()
    params = registry.init_params(jax.random.PRNGKey(1), cfg)
    srv = ContinuousBatcher(params, cfg, max_slots=2, max_len=32)
    for _ in range(5):
        srv.submit(rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                   max_new=3)
    # first step admits at most 2
    srv.step()
    assert sum(r is not None for r in srv.active) <= 2
    done = srv.run()
    assert len(done) == 5
    assert all(len(r.out) == 3 for r in done)


def test_eos_early_stop(rng):
    cfg = get_config("mamba2-130m").reduced()
    params = registry.init_params(jax.random.PRNGKey(2), cfg)
    prompt = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    ref = generate_single(params, cfg, prompt, 8, max_len=32)
    eos = ref[2]   # force an early stop at the 3rd generated token
    srv = ContinuousBatcher(params, cfg, max_slots=1, max_len=32)
    srv.submit(prompt, max_new=8, eos_id=int(eos))
    done = srv.run()
    assert done[0].out[-1] == eos
    assert len(done[0].out) <= 8
