"""Continuous-batching server: parity with single-request generation.

Bucketed prefill (the default) pads prompts to a power-of-two ladder and
prefills same-tick admits as one vmapped program per bucket; every test
here demands greedy outputs *bit-identical* to running each request alone
(`generate_single`, which never pads), across all decoder-only LM families
— dense, SWA-dense (gemma3 local:global pattern), MoE, SSM, hybrid.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.serving import ContinuousBatcher, generate_single
from repro.models import registry

# one representative per decoder-only LM family / attention pattern
LM_ARCHS = ["h2o-danube-3-4b",          # dense, full attention
            "gemma3-12b",               # dense, 5:1 SWA local:global
            "llama4-scout-17b-a16e",    # moe
            "mamba2-130m",              # ssm
            "hymba-1.5b"]               # hybrid (attn + ssm branches)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_continuous_batching_matches_single(arch, rng):
    """Greedy outputs under slot batching + bucketed prefill == running
    each request alone, despite different prompt lengths, admission times
    and retirements."""
    cfg = get_config(arch).reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 3, 7)]
    max_new = [6, 4, 8, 5]

    srv = ContinuousBatcher(params, cfg, max_slots=2, max_len=64,
                            min_bucket=4)
    for p, m in zip(prompts, max_new):
        srv.submit(p, max_new=m)
    done = srv.run()
    assert len(done) == 4

    for req, p, m in zip(done, prompts, max_new):
        ref = generate_single(params, cfg, p, m, max_len=64)
        assert req.out == ref, (req.rid, req.out, ref)


def test_bucketed_compile_bound_and_parity(rng):
    """A 16-request stream with 8 distinct prompt lengths compiles at most
    len(buckets) prefill programs; the per-length oracle pays one compile
    per distinct length; outputs are bit-identical between the two."""
    cfg = get_config("h2o-danube-3-4b").reduced()
    params = registry.init_params(jax.random.PRNGKey(3), cfg)
    lengths = [3, 4, 5, 7, 9, 12, 17, 23] * 2
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lengths]

    bucketed = ContinuousBatcher(params, cfg, max_slots=4, max_len=64,
                                 min_bucket=4)
    oracle = ContinuousBatcher(params, cfg, max_slots=4, max_len=64,
                               min_bucket=0)
    for p in prompts:
        bucketed.submit(p, max_new=4)
        oracle.submit(p, max_new=4)
    outs_b = {r.rid: r.out for r in bucketed.run()}
    outs_o = {r.rid: r.out for r in oracle.run()}
    assert len(outs_b) == len(prompts)
    assert outs_b == outs_o

    assert bucketed.buckets == (4, 8, 16, 32, 64)
    assert bucketed.prefill_compiles <= len(bucketed.buckets)
    assert oracle.prefill_compiles == len(set(lengths))
    # the admission fix: same-tick same-bucket admits batch as ONE program
    assert any(size > 1 for size in bucketed.group_admits), \
        bucketed.group_admits
    assert set(oracle.group_admits) == {1}
    assert sum(k * v for k, v in bucketed.group_admits.items()) \
        == len(prompts)


def test_max_new_one_does_not_overshoot(rng):
    """A request done at admit time (max_new=1 / eos on the prefill token)
    must retire before the same tick's decode."""
    cfg = get_config("mamba2-130m").reduced()
    params = registry.init_params(jax.random.PRNGKey(5), cfg)
    prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    for min_bucket in (8, 0):
        srv = ContinuousBatcher(params, cfg, max_slots=2, max_len=32,
                                min_bucket=min_bucket)
        srv.submit(prompt, max_new=1)
        done = srv.run()
        ref = generate_single(params, cfg, prompt, 1, max_len=32)
        assert done[0].out == ref and len(ref) == 1


def test_bucketed_group_admit_single_program(rng):
    """Same-length same-tick admits land in one bucket group: exactly one
    prefill program runs for the whole first wave."""
    cfg = get_config("mamba2-130m").reduced()
    params = registry.init_params(jax.random.PRNGKey(4), cfg)
    srv = ContinuousBatcher(params, cfg, max_slots=4, max_len=32,
                            min_bucket=8)
    for _ in range(4):
        srv.submit(rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                   max_new=3)
    srv.step()
    assert srv.group_admits == {4: 1}
    assert srv.bucket_hist == {8: 1}
    assert srv.prefill_compiles == 1
    done = srv.run()
    assert len(done) == 4


def test_server_respects_slot_limit(rng):
    cfg = get_config("mamba2-130m").reduced()
    params = registry.init_params(jax.random.PRNGKey(1), cfg)
    srv = ContinuousBatcher(params, cfg, max_slots=2, max_len=32)
    for _ in range(5):
        srv.submit(rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                   max_new=3)
    # first step admits at most 2
    srv.step()
    assert sum(r is not None for r in srv.active) <= 2
    done = srv.run()
    assert len(done) == 5
    assert all(len(r.out) == 3 for r in done)


def test_eos_early_stop(rng):
    cfg = get_config("mamba2-130m").reduced()
    params = registry.init_params(jax.random.PRNGKey(2), cfg)
    prompt = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    ref = generate_single(params, cfg, prompt, 8, max_len=32)
    eos = ref[2]   # force an early stop at the 3rd generated token
    srv = ContinuousBatcher(params, cfg, max_slots=1, max_len=32)
    srv.submit(prompt, max_new=8, eos_id=int(eos))
    done = srv.run()
    assert done[0].out[-1] == eos
    assert len(done[0].out) <= 8
