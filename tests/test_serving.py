"""Continuous-batching server: parity with single-request generation.

Bucketed prefill (the default) pads prompts to a power-of-two ladder and
prefills same-tick admits as one vmapped program per bucket; ring decode
(the default) keeps W-slot ring buffers for SWA layers and ladder-bucketed
K-extents for full-attention layers. Every test here demands greedy
outputs *bit-identical* to running each request alone (`generate_single`,
which never pads or rings), across all decoder-only LM families — dense,
SWA-dense (gemma3 local:global pattern), MoE, SSM, hybrid.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.serving import ContinuousBatcher, generate_single
from repro.models import registry

# one representative per decoder-only LM family / attention pattern
LM_ARCHS = ["h2o-danube-3-4b",          # dense, full attention
            "gemma3-12b",               # dense, 5:1 SWA local:global
            "llama4-scout-17b-a16e",    # moe
            "mamba2-130m",              # ssm
            "hymba-1.5b"]               # hybrid (attn + ssm branches)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_continuous_batching_matches_single(arch, rng):
    """Greedy outputs under slot batching + bucketed prefill == running
    each request alone, despite different prompt lengths, admission times
    and retirements."""
    cfg = get_config(arch).reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 3, 7)]
    max_new = [6, 4, 8, 5]

    srv = ContinuousBatcher(params, cfg, max_slots=2, max_len=64,
                            min_bucket=4)
    for p, m in zip(prompts, max_new):
        srv.submit(p, max_new=m)
    done = srv.run()
    assert len(done) == 4
    assert srv.decode_compiles <= max(1, len(srv.decode_buckets))

    for req, p, m in zip(done, prompts, max_new):
        ref = generate_single(params, cfg, p, m, max_len=64)
        assert req.out == ref, (req.rid, req.out, ref)


def test_bucketed_compile_bound_and_parity(rng):
    """A 16-request stream with 8 distinct prompt lengths compiles at most
    len(buckets) prefill programs; the per-length oracle pays one compile
    per distinct length; outputs are bit-identical between the two."""
    cfg = get_config("h2o-danube-3-4b").reduced()
    params = registry.init_params(jax.random.PRNGKey(3), cfg)
    lengths = [3, 4, 5, 7, 9, 12, 17, 23] * 2
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lengths]

    bucketed = ContinuousBatcher(params, cfg, max_slots=4, max_len=64,
                                 min_bucket=4)
    oracle = ContinuousBatcher(params, cfg, max_slots=4, max_len=64,
                               min_bucket=0)
    for p in prompts:
        bucketed.submit(p, max_new=4)
        oracle.submit(p, max_new=4)
    outs_b = {r.rid: r.out for r in bucketed.run()}
    outs_o = {r.rid: r.out for r in oracle.run()}
    assert len(outs_b) == len(prompts)
    assert outs_b == outs_o

    assert bucketed.buckets == (4, 8, 16, 32, 64)
    assert bucketed.prefill_compiles <= len(bucketed.buckets)
    assert oracle.prefill_compiles == len(set(lengths))
    # the admission fix: same-tick same-bucket admits batch as ONE program
    assert any(size > 1 for size in bucketed.group_admits), \
        bucketed.group_admits
    assert set(oracle.group_admits) == {1}
    assert sum(k * v for k, v in bucketed.group_admits.items()) \
        == len(prompts)


def test_max_new_one_does_not_overshoot(rng):
    """A request done at admit time (max_new=1 / eos on the prefill token)
    must retire before the same tick's decode."""
    cfg = get_config("mamba2-130m").reduced()
    params = registry.init_params(jax.random.PRNGKey(5), cfg)
    prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    for min_bucket in (8, 0):
        srv = ContinuousBatcher(params, cfg, max_slots=2, max_len=32,
                                min_bucket=min_bucket)
        srv.submit(prompt, max_new=1)
        done = srv.run()
        ref = generate_single(params, cfg, prompt, 1, max_len=32)
        assert done[0].out == ref and len(ref) == 1


def test_bucketed_group_admit_single_program(rng):
    """Same-length same-tick admits land in one bucket group: exactly one
    prefill program runs for the whole first wave."""
    cfg = get_config("mamba2-130m").reduced()
    params = registry.init_params(jax.random.PRNGKey(4), cfg)
    srv = ContinuousBatcher(params, cfg, max_slots=4, max_len=32,
                            min_bucket=8)
    for _ in range(4):
        srv.submit(rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                   max_new=3)
    srv.step()
    assert srv.group_admits == {4: 1}
    assert srv.bucket_hist == {8: 1}
    assert srv.prefill_compiles == 1
    done = srv.run()
    assert len(done) == 4


def test_server_respects_slot_limit(rng):
    cfg = get_config("mamba2-130m").reduced()
    params = registry.init_params(jax.random.PRNGKey(1), cfg)
    srv = ContinuousBatcher(params, cfg, max_slots=2, max_len=32)
    for _ in range(5):
        srv.submit(rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                   max_new=3)
    # first step admits at most 2
    srv.step()
    assert sum(r is not None for r in srv.active) <= 2
    done = srv.run()
    assert len(done) == 5
    assert all(len(r.out) == 3 for r in done)


@pytest.mark.parametrize("arch", ["gemma3-12b", "hymba-1.5b",
                                  "llama4-scout-17b-a16e"])
def test_ring_decode_matches_uniform(arch, rng):
    """Per-layer-kind decode (SWA ring buffers + ladder-bucketed K-extent)
    == the uniform full-cache decode, greedily, over a mixed stream; ring
    decode compiles stay on the K-extent ladder, uniform compiles once."""
    cfg = get_config(arch).reduced()
    params = registry.init_params(jax.random.PRNGKey(7), cfg)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9, 13, 3)]
    max_new = [10, 6, 4, 8]

    outs = {}
    for mode in ("ring", "uniform"):
        srv = ContinuousBatcher(params, cfg, max_slots=2, max_len=64,
                                min_bucket=4, decode_mode=mode)
        for p, m in zip(prompts, max_new):
            srv.submit(p, max_new=m)
        outs[mode] = {r.rid: r.out for r in srv.run()}
        if mode == "uniform":
            assert srv.decode_compiles == 1
            assert srv.decode_buckets == ()
        else:
            assert srv.decode_compiles <= max(1, len(srv.decode_buckets))
    assert outs["ring"] == outs["uniform"]


def test_ring_decode_wraps_past_window(rng):
    """Generations running far past a small sliding window W: the ring
    wraps (slot reuse, install gather of only the last W prompt tokens)
    and still matches uniform decode and generate_single greedily."""
    from repro.types import ModelConfig
    cfg = ModelConfig(name="tiny-swa", family="dense", num_layers=2,
                      d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
                      vocab_size=256, sliding_window=8, global_every=2)
    params = registry.init_params(jax.random.PRNGKey(12), cfg)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (3, 17)]                  # 17 > W: install wraps
    outs = {}
    for mode in ("ring", "uniform"):
        srv = ContinuousBatcher(params, cfg, max_slots=2, max_len=64,
                                min_bucket=4, decode_mode=mode)
        for p in prompts:
            srv.submit(p, max_new=30)             # pos runs to ~47 >> W
        outs[mode] = {r.rid: r.out for r in srv.run()}
    assert outs["ring"] == outs["uniform"]
    for rid, p in enumerate(prompts):
        ref = generate_single(params, cfg, p, 30, max_len=64)
        assert outs["ring"][rid] == ref


def test_decode_compile_count_bounded_by_ladder(rng):
    """Generations long enough to cross several K-extent rungs still
    compile at most len(decode_buckets) decode programs (hymba: global +
    SWA + SSM layers all in play), with outputs matching the oracle."""
    cfg = get_config("hymba-1.5b").reduced()
    params = registry.init_params(jax.random.PRNGKey(8), cfg)
    lengths, max_new = (3, 9, 21), (20, 12, 30)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lengths]

    srv = ContinuousBatcher(params, cfg, max_slots=2, max_len=64,
                            min_bucket=4, decode_mode="ring")
    for p, m in zip(prompts, max_new):
        srv.submit(p, max_new=m)
    done = srv.run()
    assert srv.decode_buckets == (4, 8, 16, 32, 64)
    assert 2 <= srv.decode_compiles <= len(srv.decode_buckets)
    for req, p, m in zip(done, prompts, max_new):
        assert req.out == generate_single(params, cfg, p, m, max_len=64)


def test_submit_rejects_oversized_without_killing_server(rng):
    """An oversized request fails at submit() with ValueError (not a
    mid-run assert) and valid in-flight requests keep serving."""
    cfg = get_config("mamba2-130m").reduced()
    params = registry.init_params(jax.random.PRNGKey(9), cfg)
    prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    srv = ContinuousBatcher(params, cfg, max_slots=2, max_len=32)
    good = srv.submit(prompt, max_new=4)
    srv.step()                               # good request is in flight
    with pytest.raises(ValueError, match="too long"):
        srv.submit(rng.integers(0, cfg.vocab_size, 30).astype(np.int32),
                   max_new=8)                # 30 + 8 > 32
    with pytest.raises(ValueError, match="empty"):
        srv.submit(np.zeros((0,), np.int32), max_new=4)
    with pytest.raises(ValueError, match="1-D"):
        srv.submit(np.zeros((2, 3), np.int32), max_new=4)
    with pytest.raises(ValueError, match="1-D"):
        srv.submit(np.int32(7), max_new=4)
    done = srv.run()
    assert [r.rid for r in done] == [good]
    assert done[0].out == generate_single(params, cfg, prompt, 4,
                                          max_len=32)


def test_submit_rejects_max_new_zero(rng):
    """max_new=0 used to prefill anyway and emit 1 token (prefill's argmax
    lands in out before Request.done is consulted); now it never enters."""
    cfg = get_config("mamba2-130m").reduced()
    params = registry.init_params(jax.random.PRNGKey(10), cfg)
    srv = ContinuousBatcher(params, cfg, max_slots=2, max_len=32)
    with pytest.raises(ValueError, match="max_new"):
        srv.submit(rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                   max_new=0)
    assert srv.queue == [] and srv.run() == []


def test_run_exhaustion_surfaces_pending(rng):
    """run(max_iters) running out no longer silently drops queued and
    in-flight requests: it warns, pending() lists them, and a later run()
    resumes them to the same greedy outputs."""
    cfg = get_config("mamba2-130m").reduced()
    params = registry.init_params(jax.random.PRNGKey(11), cfg)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (4, 6, 5)]
    srv = ContinuousBatcher(params, cfg, max_slots=1, max_len=32)
    for p in prompts:
        srv.submit(p, max_new=6)
    with pytest.warns(RuntimeWarning, match="exhausted"):
        done = srv.run(max_iters=2)
    assert len(done) < 3
    assert len(done) + len(srv.pending()) == 3
    done = srv.run()                          # resumes, no warning
    assert len(done) == 3 and srv.pending() == []
    for req, p in zip(done, prompts):
        assert req.out == generate_single(params, cfg, p, 6, max_len=32)


def test_eos_early_stop(rng):
    cfg = get_config("mamba2-130m").reduced()
    params = registry.init_params(jax.random.PRNGKey(2), cfg)
    prompt = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    ref = generate_single(params, cfg, prompt, 8, max_len=32)
    eos = ref[2]   # force an early stop at the 3rd generated token
    srv = ContinuousBatcher(params, cfg, max_slots=1, max_len=32)
    srv.submit(prompt, max_new=8, eos_id=int(eos))
    done = srv.run()
    assert done[0].out[-1] == eos
    assert len(done[0].out) <= 8


@pytest.mark.parametrize("decode_mode", ["ring", "uniform"])
@pytest.mark.parametrize("decode_kernel", ["pallas", "einsum"])
def test_prompt_shorter_than_window_parity(decode_mode, decode_kernel, rng):
    """Prompts shorter than the ring window (P < W) leave never-written
    slots — install must keep them inert. Greedy parity with
    ``generate_single`` in both decode modes and both decode kernels,
    down to a single-token prompt."""
    cfg = get_config("gemma3-12b").reduced()      # SWA: W = min(64, max_len)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (1, 3, 13)]               # all < W = 32
    max_new = [4, 6, 3]
    srv = ContinuousBatcher(params, cfg, max_slots=2, max_len=32,
                            min_bucket=4, decode_mode=decode_mode,
                            decode_kernel=decode_kernel)
    for p, m in zip(prompts, max_new):
        srv.submit(p, max_new=m)
    done = srv.run()
    assert len(done) == len(prompts)
    for req, p, m in zip(done, prompts, max_new):
        assert req.out == generate_single(params, cfg, p, m, max_len=32), \
            (decode_mode, decode_kernel, req.rid)


def test_window_one_ring_parity(rng):
    """W = 1 edge: each SWA layer's ring holds only the current token."""
    import dataclasses
    cfg = dataclasses.replace(get_config("gemma3-12b").reduced(),
                              sliding_window=1)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (1, 5)]
    srv = ContinuousBatcher(params, cfg, max_slots=2, max_len=32,
                            min_bucket=4)
    for p in prompts:
        srv.submit(p, max_new=4)
    done = srv.run()
    assert len(done) == 2
    for req, p in zip(done, prompts):
        assert req.out == generate_single(params, cfg, p, 4, max_len=32)


def test_ring_install_short_prompt_slots(rng):
    """Regression (PR 7): installing a P < W prompt used to leave the
    never-written ring slots holding a clipped gather of position 0;
    they must be exactly zero (decode masks them either way, but the
    cache state must not depend on install history)."""
    from repro.models import lm
    cfg = get_config("gemma3-12b").reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    P = 3
    srv = ContinuousBatcher(params, cfg, max_slots=1, max_len=32,
                            min_bucket=4)
    srv.submit(rng.integers(0, cfg.vocab_size, P).astype(np.int32),
               max_new=2)
    srv._admit()                                   # install, no decode yet
    W = srv.cache["k_win"].shape[2]
    assert P < W
    unwritten = np.asarray(lm.ring_source_positions(P - 1, W)).ravel() < 0
    assert unwritten.any()
    for key in ("k_win", "v_win"):
        buf = np.asarray(srv.cache[key])[:, 0]     # (Lw, W, kv, hd), slot 0
        assert (buf[:, unwritten] == 0).all(), key
        assert np.abs(buf[:, ~unwritten]).max() > 0, key
