"""Scan/vmap client-execution engine vs the legacy loop (parity oracle).

The engine (core/fed_engine.py) must reproduce the per-iteration dispatch
path to float32 tolerance: same local updates, same losses, same simulator
trajectories — including the int8 delta-compression roundtrip, non-uniform
per-client H (the padded masked-scan program), and the shard_map'ed round
on a single-device mesh.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import fed_engine, fedasync, fedavg, simulator
from repro.core.simulator import JETSON_FLEET_HMDB51
from repro.data import BatchLoader, SyntheticLMDataset, stack_batches
from repro.models import registry
from repro.types import FedConfig, ModelConfig

TINY = ModelConfig(name="engine-test-tiny", family="dense", num_layers=1,
                   d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                   vocab_size=64)


def tree_allclose(a, b, rtol=1e-5, atol=1e-5):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32),
                                   rtol=rtol, atol=atol)


@pytest.fixture(scope="module")
def setup():
    params = registry.init_params(jax.random.PRNGKey(0), TINY)
    fed = FedConfig(num_clients=4, global_epochs=6, local_iters_min=1,
                    local_iters_max=3, lr=0.01)
    ds = SyntheticLMDataset(vocab=TINY.vocab_size, seq_len=8, seed=0)
    return params, fed, ds


def test_scan_client_matches_loop(setup):
    params, fed, ds = setup
    batches = list(ds.batches(2, 3, seed=7))
    w_loop, tau, losses_loop = fedasync.client_update(
        params, 5, iter(batches), TINY, fed, num_iters=3)
    run = fed_engine.make_client_run(TINY, fed)
    w_scan, losses_scan = run(params, stack_batches(iter(batches)))
    assert tau == 5
    np.testing.assert_allclose(np.asarray(losses_scan), losses_loop,
                               rtol=1e-4)
    tree_allclose(w_loop, w_scan)


def test_scan_nonuniform_H_uses_static_cache(setup):
    params, fed, ds = setup
    # a private instance: make_client_run memoizes engines globally, which
    # would leak compile-cache entries from other tests into the count
    run = fed_engine.ClientRun(TINY, fed)
    for H in (1, 3, 3):     # repeat H=3: cache hit, no new entry
        batches = list(ds.batches(2, H, seed=H))
        w_loop, _, losses_loop = fedasync.client_update(
            params, 0, iter(batches), TINY, fed, num_iters=H)
        w_scan, losses_scan = run(params, stack_batches(iter(batches)))
        assert losses_scan.shape == (H,)
        np.testing.assert_allclose(np.asarray(losses_scan), losses_loop,
                                   rtol=1e-4)
        tree_allclose(w_loop, w_scan)
    # one compiled program per distinct (H, trainable)
    assert run.num_compiled == 2


def test_vmap_round_matches_loop(setup):
    params, fed, ds = setup
    batches = [list(ds.batches(2, fed.local_iters_max, seed=k))
               for k in range(3)]
    sizes = [10, 30, 60]
    g_loop, l_loop = fedavg.fedavg_round_loop(
        params, [iter(b) for b in batches], TINY, fed, data_sizes=sizes)
    g_vmap, l_vmap = fedavg.fedavg_round(
        params, [iter(b) for b in batches], TINY, fed, data_sizes=sizes)
    tree_allclose(g_loop, g_vmap)
    np.testing.assert_allclose(l_vmap, l_loop, rtol=1e-4)


def test_vmap_round_ragged_client_pads(setup):
    """A client that runs out of data no longer breaks the batched round:
    its stack pads to H_max and the iteration mask absorbs the gap."""
    params, fed, ds = setup
    batches = [list(ds.batches(2, fed.local_iters_max, seed=0)),
               list(ds.batches(2, 1, seed=1))]        # ragged H
    g_loop, l_loop = fedavg.fedavg_round_loop(
        params, [iter(b) for b in batches], TINY, fed)
    g_new, l_new = fedavg.fedavg_round(
        params, [iter(b) for b in batches], TINY, fed)
    assert [len(l) for l in l_new] == [len(l) for l in l_loop]
    tree_allclose(g_loop, g_new)


def test_vmap_round_ragged_within_client_falls_back(setup):
    """Batch shapes that don't stack within one client (e.g. a trailing
    partial batch) drop that client to the per-iteration loop; generators
    must survive (raggedness detected after materialization)."""
    params, fed, ds = setup
    uniform = list(ds.batches(2, fed.local_iters_max, seed=0))
    ragged = list(ds.batches(2, 2, seed=1)) + list(ds.batches(1, 1, seed=2))
    g_loop, l_loop = fedavg.fedavg_round_loop(
        params, [iter(uniform), iter(ragged)], TINY, fed)
    g_new, l_new = fedavg.fedavg_round(
        params, (b for b in [iter(uniform), iter(ragged)]), TINY, fed)
    assert [len(l) for l in l_new] == [len(l) for l in l_loop]
    np.testing.assert_allclose(np.concatenate([np.asarray(l)
                                               for l in l_new]),
                               np.concatenate([np.asarray(l)
                                               for l in l_loop]), rtol=1e-4)
    tree_allclose(g_loop, g_new)


def test_stack_error_mentions_padded_path(setup):
    """The mixed-shape error must point at pad_client_batches (the padded
    masked-scan round), not at falling back to the per-client loop."""
    params, fed, ds = setup
    stacks = [stack_batches(iter(list(ds.batches(2, h, seed=h))))
              for h in (3, 1)]
    with pytest.raises(ValueError, match="pad_client_batches"):
        fed_engine.stack_client_batches(stacks)
    # and padding refuses mismatched keys even when leaf shapes line up
    renamed = {f"x_{k}": v for k, v in stacks[1].items()}
    with pytest.raises(ValueError, match="structure"):
        fed_engine.pad_client_batches([stacks[0], renamed])


def test_padded_batch_matches_loop(setup):
    """run_batch: clients with H^k < H_max agree with the per-client loop
    oracle; losses past H^k are NaN; the compile cache holds ONE program
    per round shape across different H vectors."""
    params, fed, ds = setup
    run = fed_engine.ClientRun(TINY, fed)   # private: isolate cache counts
    for Hs in ([3, 1, 2], [1, 2, 1], [2, 3, 3]):
        blists = [list(ds.batches(2, h, seed=10 * h + i))
                  for i, h in enumerate(Hs)]
        w_news, losses = run.run_batch(
            params, [stack_batches(iter(b)) for b in blists])
        losses = np.asarray(losses)
        assert losses.shape == (len(Hs), fed.local_iters_max)
        for j, (h, bl) in enumerate(zip(Hs, blists)):
            w_loop, _, l_loop = fedasync.client_update(
                params, 0, iter(bl), TINY, fed, num_iters=h)
            np.testing.assert_allclose(losses[j, :h], l_loop, rtol=1e-4)
            assert np.all(np.isnan(losses[j, h:]))
            tree_allclose(jax.tree_util.tree_map(lambda a, j=j: a[j],
                                                 w_news), w_loop)
    # H^k is traced, not a compile key: 3 different H vectors, 1 program
    assert run.num_compiled == 1


def test_caller_iters_win_over_stack_lengths(setup):
    """An explicit iters= with unequal-length stacks truncates to the
    requested H^k — padding must not silently overwrite it."""
    params, fed, ds = setup
    run = fed_engine.make_client_run(TINY, fed)
    blists = [list(ds.batches(2, 3, seed=1)), list(ds.batches(2, 2, seed=2))]
    stacks = [stack_batches(iter(b)) for b in blists]
    w_news, losses = run.run_batch(params, stacks, iters=[2, 1])
    for j, (h, bl) in enumerate(zip([2, 1], blists)):
        w_loop, _, l_loop = fedasync.client_update(
            params, 0, iter(bl), TINY, fed, num_iters=h)
        np.testing.assert_allclose(np.asarray(losses)[j, :h], l_loop,
                                   rtol=1e-4)
        tree_allclose(jax.tree_util.tree_map(lambda a, j=j: a[j], w_news),
                      w_loop)


def test_padded_compression_roundtrip_parity(setup):
    """The int8 delta roundtrip applied to padded-batch outputs matches
    the loop oracle's compressed updates (what the async server sees)."""
    from repro.core.compression import roundtrip
    params, fed, ds = setup
    Hs = [3, 1]
    blists = [list(ds.batches(2, h, seed=h)) for h in Hs]
    run = fed_engine.make_client_run(TINY, fed)
    w_news, _ = run.run_batch(
        params, [stack_batches(iter(b)) for b in blists])
    for j, (h, bl) in enumerate(zip(Hs, blists)):
        w_loop, _, _ = fedasync.client_update(params, 0, iter(bl), TINY,
                                              fed, num_iters=h)
        w_pad = jax.tree_util.tree_map(lambda a, j=j: a[j], w_news)
        rt_pad, _ = roundtrip(w_pad, params, 8)
        rt_loop, _ = roundtrip(w_loop, params, 8)
        tree_allclose(rt_pad, rt_loop, rtol=1e-3, atol=1e-4)


def test_heterogeneous_round_matches_loop(setup):
    """A fleet with per-client H^k (including an out-of-data client) runs
    as ONE padded program with loop-oracle parity — no per-client
    fallback."""
    params, fed, ds = setup
    batches = [list(ds.batches(2, 3, seed=0)), list(ds.batches(2, 1, seed=1)),
               [], list(ds.batches(2, 2, seed=2))]
    sizes = [10, 30, 20, 40]
    g_loop, l_loop = fedavg.fedavg_round_loop(
        params, [iter(b) for b in batches], TINY, fed, data_sizes=sizes)
    engine = fed_engine.SyncRound(TINY, fed)    # private: count compiles
    g_pad, l_pad = fedavg.fedavg_round(
        params, [iter(b) for b in batches], TINY, fed, engine=engine,
        data_sizes=sizes)
    assert [len(l) for l in l_pad] == [3, 1, 0, 2]
    assert engine.num_compiled == 1             # one batched program
    np.testing.assert_allclose(
        np.concatenate([np.asarray(l) for l in l_pad]),
        np.concatenate([np.asarray(l) for l in l_loop]), rtol=1e-4)
    tree_allclose(g_loop, g_pad)


def test_sharded_round_single_device_smoke(setup):
    """shard_map round on this host's (1-device) fleet mesh: same layout
    and psum-reduced weighted average as production, loop-oracle parity
    for a heterogeneous H^k fleet."""
    from repro.launch.mesh import make_fleet_mesh
    params, fed, ds = setup
    mesh = make_fleet_mesh()
    assert mesh.axis_names == ("clients",)
    batches = [list(ds.batches(2, h, seed=h)) for h in (3, 1, 2)]
    sizes = [10, 30, 60]
    engine = fed_engine.make_sharded_sync_round(TINY, fed, mesh=mesh)
    g_loop, l_loop = fedavg.fedavg_round_loop(
        params, [iter(b) for b in batches], TINY, fed, data_sizes=sizes)
    g_sh, l_sh = fedavg.fedavg_round(
        params, [iter(b) for b in batches], TINY, fed, engine=engine,
        data_sizes=sizes)
    assert [len(l) for l in l_sh] == [len(l) for l in l_loop]
    tree_allclose(g_loop, g_sh)
    # memoized: same (cfg, fed, mesh) -> same engine instance
    assert fed_engine.make_sharded_sync_round(TINY, fed, mesh=mesh) \
        is engine


def test_run_sync_shard_engine_parity(setup):
    params, fed, ds = setup
    ra = simulator.run_sync(params, TINY, fed, JETSON_FLEET_HMDB51,
                            _fleet_data(ds, fed), engine="shard")
    rb = simulator.run_sync(params, TINY, fed, JETSON_FLEET_HMDB51,
                            _fleet_data(ds, fed), engine="loop")
    assert ra.wall_clock_s == rb.wall_clock_s
    np.testing.assert_allclose([h[2] for h in ra.history],
                               [h[2] for h in rb.history],
                               rtol=1e-3, atol=1e-4)
    tree_allclose(ra.params, rb.params, rtol=1e-3, atol=1e-4)


def _fleet_data(ds, fed):
    return [BatchLoader(ds, 2, steps=4, seed=k)
            for k in range(fed.num_clients)]


@pytest.mark.parametrize("compress_bits", [0, 8])
def test_run_async_engine_parity(setup, compress_bits):
    params, fed, ds = setup
    import dataclasses
    fed = dataclasses.replace(fed, compress_bits=compress_bits)
    ra = simulator.run_async(params, TINY, fed, JETSON_FLEET_HMDB51,
                             _fleet_data(ds, fed), engine="scan")
    rb = simulator.run_async(params, TINY, fed, JETSON_FLEET_HMDB51,
                             _fleet_data(ds, fed), engine="loop")
    # identical event order / virtual clock, float32-level numerics
    assert ra.wall_clock_s == rb.wall_clock_s
    assert ra.staleness_hist == rb.staleness_hist
    np.testing.assert_allclose([h[2] for h in ra.history],
                               [h[2] for h in rb.history],
                               rtol=1e-3, atol=1e-4)
    tree_allclose(ra.params, rb.params, rtol=1e-3, atol=1e-4)


def test_run_sync_engine_parity(setup):
    params, fed, ds = setup
    ra = simulator.run_sync(params, TINY, fed, JETSON_FLEET_HMDB51,
                            _fleet_data(ds, fed), engine="scan")
    rb = simulator.run_sync(params, TINY, fed, JETSON_FLEET_HMDB51,
                            _fleet_data(ds, fed), engine="loop")
    assert ra.wall_clock_s == rb.wall_clock_s
    np.testing.assert_allclose([h[2] for h in ra.history],
                               [h[2] for h in rb.history],
                               rtol=1e-3, atol=1e-4)
    tree_allclose(ra.params, rb.params, rtol=1e-3, atol=1e-4)


def test_unstack_clients_matches_eager_slices():
    """One jitted dispatch must split a client-stacked pytree exactly like
    per-client eager ``a[j]`` slicing (the async burst's fan-out)."""
    rng = np.random.default_rng(0)
    stacked = {"w": jnp.asarray(rng.standard_normal((3, 4, 2)), jnp.float32),
               "b": jnp.asarray(rng.standard_normal((3, 5)), jnp.float32)}
    run = fed_engine.ClientRun(TINY, FedConfig(num_clients=3))
    out = run.unstack(stacked, 3)
    assert len(out) == 3
    for j in range(3):
        for got, ref in zip(jax.tree_util.tree_leaves(out[j]),
                            jax.tree_util.tree_leaves(
                                jax.tree_util.tree_map(
                                    lambda a: a[j], stacked))):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_server_mix_shared_across_configs():
    """server_receive(mix=None) must reuse one jitted mix — the program is
    config-independent (beta_t is an argument), so no per-receive or even
    per-FedConfig recompiles."""
    assert fedasync.make_server_update(FedConfig(mixing_beta=0.7)) is \
        fedasync.make_server_update(FedConfig(mixing_beta=0.5))
