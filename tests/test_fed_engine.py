"""Scan/vmap client-execution engine vs the legacy loop (parity oracle).

The engine (core/fed_engine.py) must reproduce the per-iteration dispatch
path to float32 tolerance: same local updates, same losses, same simulator
trajectories — including the int8 delta-compression roundtrip and
non-uniform per-client H.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import fed_engine, fedasync, fedavg, simulator
from repro.core.simulator import JETSON_FLEET_HMDB51
from repro.data import BatchLoader, SyntheticLMDataset, stack_batches
from repro.models import registry
from repro.types import FedConfig, ModelConfig

TINY = ModelConfig(name="engine-test-tiny", family="dense", num_layers=1,
                   d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                   vocab_size=64)


def tree_allclose(a, b, rtol=1e-5, atol=1e-5):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la, np.float32),
                                   np.asarray(lb, np.float32),
                                   rtol=rtol, atol=atol)


@pytest.fixture(scope="module")
def setup():
    params = registry.init_params(jax.random.PRNGKey(0), TINY)
    fed = FedConfig(num_clients=4, global_epochs=6, local_iters_min=1,
                    local_iters_max=3, lr=0.01)
    ds = SyntheticLMDataset(vocab=TINY.vocab_size, seq_len=8, seed=0)
    return params, fed, ds


def test_scan_client_matches_loop(setup):
    params, fed, ds = setup
    batches = list(ds.batches(2, 3, seed=7))
    w_loop, tau, losses_loop = fedasync.client_update(
        params, 5, iter(batches), TINY, fed, num_iters=3)
    run = fed_engine.make_client_run(TINY, fed)
    w_scan, losses_scan = run(params, stack_batches(iter(batches)))
    assert tau == 5
    np.testing.assert_allclose(np.asarray(losses_scan), losses_loop,
                               rtol=1e-4)
    tree_allclose(w_loop, w_scan)


def test_scan_nonuniform_H_uses_static_cache(setup):
    params, fed, ds = setup
    # a private instance: make_client_run memoizes engines globally, which
    # would leak compile-cache entries from other tests into the count
    run = fed_engine.ClientRun(TINY, fed)
    for H in (1, 3, 3):     # repeat H=3: cache hit, no new entry
        batches = list(ds.batches(2, H, seed=H))
        w_loop, _, losses_loop = fedasync.client_update(
            params, 0, iter(batches), TINY, fed, num_iters=H)
        w_scan, losses_scan = run(params, stack_batches(iter(batches)))
        assert losses_scan.shape == (H,)
        np.testing.assert_allclose(np.asarray(losses_scan), losses_loop,
                                   rtol=1e-4)
        tree_allclose(w_loop, w_scan)
    # one compiled program per distinct (H, trainable)
    assert run.num_compiled == 2


def test_vmap_round_matches_loop(setup):
    params, fed, ds = setup
    batches = [list(ds.batches(2, fed.local_iters_max, seed=k))
               for k in range(3)]
    sizes = [10, 30, 60]
    g_loop, l_loop = fedavg.fedavg_round_loop(
        params, [iter(b) for b in batches], TINY, fed, data_sizes=sizes)
    g_vmap, l_vmap = fedavg.fedavg_round(
        params, [iter(b) for b in batches], TINY, fed, data_sizes=sizes)
    tree_allclose(g_loop, g_vmap)
    np.testing.assert_allclose(l_vmap, l_loop, rtol=1e-4)


def test_vmap_round_ragged_falls_back(setup):
    """A client that runs out of data drops to the per-client scan path."""
    params, fed, ds = setup
    batches = [list(ds.batches(2, fed.local_iters_max, seed=0)),
               list(ds.batches(2, 1, seed=1))]        # ragged H
    g_loop, l_loop = fedavg.fedavg_round_loop(
        params, [iter(b) for b in batches], TINY, fed)
    g_new, l_new = fedavg.fedavg_round(
        params, [iter(b) for b in batches], TINY, fed)
    assert [len(l) for l in l_new] == [len(l) for l in l_loop]
    tree_allclose(g_loop, g_new)


def test_vmap_round_ragged_within_client_falls_back(setup):
    """Batch shapes that don't stack within one client (e.g. a trailing
    partial batch) drop that client to the per-iteration loop; generators
    must survive (raggedness detected after materialization)."""
    params, fed, ds = setup
    uniform = list(ds.batches(2, fed.local_iters_max, seed=0))
    ragged = list(ds.batches(2, 2, seed=1)) + list(ds.batches(1, 1, seed=2))
    g_loop, l_loop = fedavg.fedavg_round_loop(
        params, [iter(uniform), iter(ragged)], TINY, fed)
    g_new, l_new = fedavg.fedavg_round(
        params, (b for b in [iter(uniform), iter(ragged)]), TINY, fed)
    assert [len(l) for l in l_new] == [len(l) for l in l_loop]
    np.testing.assert_allclose(np.concatenate([np.asarray(l)
                                               for l in l_new]),
                               np.concatenate([np.asarray(l)
                                               for l in l_loop]), rtol=1e-4)
    tree_allclose(g_loop, g_new)


def _fleet_data(ds, fed):
    return [BatchLoader(ds, 2, steps=4, seed=k)
            for k in range(fed.num_clients)]


@pytest.mark.parametrize("compress_bits", [0, 8])
def test_run_async_engine_parity(setup, compress_bits):
    params, fed, ds = setup
    import dataclasses
    fed = dataclasses.replace(fed, compress_bits=compress_bits)
    ra = simulator.run_async(params, TINY, fed, JETSON_FLEET_HMDB51,
                             _fleet_data(ds, fed), engine="scan")
    rb = simulator.run_async(params, TINY, fed, JETSON_FLEET_HMDB51,
                             _fleet_data(ds, fed), engine="loop")
    # identical event order / virtual clock, float32-level numerics
    assert ra.wall_clock_s == rb.wall_clock_s
    assert ra.staleness_hist == rb.staleness_hist
    np.testing.assert_allclose([h[2] for h in ra.history],
                               [h[2] for h in rb.history],
                               rtol=1e-3, atol=1e-4)
    tree_allclose(ra.params, rb.params, rtol=1e-3, atol=1e-4)


def test_run_sync_engine_parity(setup):
    params, fed, ds = setup
    ra = simulator.run_sync(params, TINY, fed, JETSON_FLEET_HMDB51,
                            _fleet_data(ds, fed), engine="scan")
    rb = simulator.run_sync(params, TINY, fed, JETSON_FLEET_HMDB51,
                            _fleet_data(ds, fed), engine="loop")
    assert ra.wall_clock_s == rb.wall_clock_s
    np.testing.assert_allclose([h[2] for h in ra.history],
                               [h[2] for h in rb.history],
                               rtol=1e-3, atol=1e-4)
    tree_allclose(ra.params, rb.params, rtol=1e-3, atol=1e-4)


def test_server_mix_shared_across_configs():
    """server_receive(mix=None) must reuse one jitted mix — the program is
    config-independent (beta_t is an argument), so no per-receive or even
    per-FedConfig recompiles."""
    assert fedasync.make_server_update(FedConfig(mixing_beta=0.7)) is \
        fedasync.make_server_update(FedConfig(mixing_beta=0.5))
