"""Shared compile cache: bucketing math + jit-pool compile accounting."""
import jax.numpy as jnp
import pytest

from repro.core import compile_cache as cc
from repro.core import fed_engine


def test_next_pow2():
    assert [cc.next_pow2(n) for n in (1, 2, 3, 4, 5, 17, 64)] \
        == [1, 2, 4, 4, 8, 32, 64]
    with pytest.raises(ValueError):
        cc.next_pow2(0)


def test_bucket_for_clamps_and_caps():
    assert cc.bucket_for(1, 8, 64) == 8      # clamped up to min_bucket
    assert cc.bucket_for(8, 8, 64) == 8
    assert cc.bucket_for(9, 8, 64) == 16
    assert cc.bucket_for(64, 8, 64) == 64
    assert cc.bucket_for(40, 8, 48) == 48    # capped at non-pow2 max_len
    with pytest.raises(ValueError):
        cc.bucket_for(65, 8, 64)             # doesn't fit the cache
    with pytest.raises(ValueError):
        cc.bucket_for(0, 8, 64)


def test_bucket_ladder_covers_every_bucket_for():
    assert cc.bucket_ladder(8, 64) == (8, 16, 32, 64)
    assert cc.bucket_ladder(8, 48) == (8, 16, 32, 48)
    assert cc.bucket_ladder(8, 8) == (8,)
    for min_bucket, max_len in ((8, 64), (4, 48), (16, 100)):
        ladder = set(cc.bucket_ladder(min_bucket, max_len))
        for P in range(1, max_len + 1):
            assert cc.bucket_for(P, min_bucket, max_len) in ladder


def test_jit_cache_counts_shapes_per_entry():
    cache = cc.JitCache()

    def dbl(x):
        return x * 2

    def neg(x):
        return -x

    cache.call("dbl", dbl, (), (jnp.zeros((2,)),))
    cache.call("dbl", dbl, (), (jnp.zeros((3,)),))   # new shape, same entry
    cache.call("dbl", dbl, (), (jnp.zeros((3,)),))   # cached
    cache.call(("tag", 1), neg, (), (jnp.zeros((2,)),))
    assert cache.count("dbl") == 2
    assert cache.count("tag") == 1       # tuple-named entries match by head
    assert cache.count("missing") == 0
    assert cache.num_compiled == 3


def test_jit_cache_counts_survive_missing_private_api():
    """Compile counts read jax.jit's private _cache_size(); if a jax
    release drops it, counts fall back to the recorded argument-signature
    sets instead of raising from every assertion at once."""
    cache = cc.JitCache()

    def dbl(x):
        return x * 2

    cache.call("dbl", dbl, (), (jnp.zeros((2,)),))
    cache.call("dbl", dbl, (), (jnp.zeros((3,)),))
    cache.call("dbl", dbl, (), (jnp.zeros((3,)),))   # cached shape
    assert cache.count("dbl") == 2
    # simulate the private API vanishing: the stored wrapper no longer
    # has a working _cache_size()
    cache._jits[("dbl", ())] = object()
    assert cache.count("dbl") == 2          # falls back to signatures
    assert cache.num_compiled == 2


def test_fed_engine_runs_on_the_shared_cache():
    """The engine's jit pool IS compile_cache.JitCache (the extraction
    changed the import, not the behavior — parity/compile-count tests in
    test_fed_engine.py pin the behavior itself)."""
    assert fed_engine._JitCache is cc.JitCache
