"""repro-lint self-tests: each rule fires on its fixture exactly once,
suppression and baselines behave, and the live tree is clean."""
from pathlib import Path

import pytest

from repro.analysis import lint

ROOT = Path(__file__).resolve().parents[1]


def only(findings, rule):
    assert [f.rule for f in findings] == [rule], findings
    return findings[0]


# ---------------------------------------------------------------- R1

def test_r1_direct_jit_decorator():
    src = ("import jax\n"
           "\n"
           "@jax.jit\n"
           "def f(x):\n"
           "    return x\n")
    f = only(lint.scan_sources({"src/repro/x.py": src}), "R1")
    assert f.line == 3
    assert "JitCache" in f.message
    assert f.key == "@jax.jit"


def test_r1_jit_in_loop():
    src = ("import jax\n"
           "\n"
           "def run(fs, x):\n"
           "    for g in fs:\n"
           "        x = jax.jit(g)(x)\n"
           "    return x\n")
    f = only(lint.scan_sources({"src/repro/x.py": src}), "R1")
    assert "loop" in f.message


def test_r1_python_scalar_into_jitted_entry():
    src = ("import jax\n"
           "\n"
           "# repro-lint: disable=R1\n"
           "@jax.jit\n"
           "def f(n):\n"
           "    return n\n"
           "\n"
           "def call(x):\n"
           "    return f(x.shape[0])\n")
    f = only(lint.scan_sources({"src/repro/x.py": src}), "R1")
    assert "retraces" in f.message and f.line == 9


def test_r1_respects_import_alias():
    src = ("from jax import jit as J\n"
           "\n"
           "@J\n"
           "def f(x):\n"
           "    return x\n")
    only(lint.scan_sources({"src/repro/x.py": src}), "R1")


def test_r1_ignores_jitcache_module():
    src = ("import jax\n"
           "w = jax.jit(lambda x: x)\n")
    assert lint.scan_sources(
        {"src/repro/core/compile_cache.py": src}) == []


# ---------------------------------------------------------------- R2

def test_r2_host_sync_reachable_from_scan():
    src = ("import jax\n"
           "\n"
           "def body(c, x):\n"
           "    return c, float(x)\n"
           "\n"
           "def run(xs):\n"
           "    return jax.lax.scan(body, 0.0, xs)\n")
    f = only(lint.scan_sources({"src/repro/x.py": src}), "R2")
    assert "float()" in f.message and f.line == 4


def test_r2_np_asarray_reachable_through_call_graph():
    # helper is only traced transitively: scan body -> helper
    src = ("import jax\n"
           "import numpy as np\n"
           "\n"
           "def helper(x):\n"
           "    return np.asarray(x)\n"
           "\n"
           "def body(c, x):\n"
           "    return c, helper(x)\n"
           "\n"
           "def run(xs):\n"
           "    return jax.lax.scan(body, 0.0, xs)\n")
    f = only(lint.scan_sources({"src/repro/x.py": src}), "R2")
    assert "numpy.asarray" in f.message and f.line == 5


def test_r2_if_on_traced_param():
    src = ("import jax\n"
           "\n"
           "def body(c, x):\n"
           "    if x:\n"
           "        return c, x\n"
           "    return c, x\n"
           "\n"
           "def run(xs):\n"
           "    return jax.lax.scan(body, 0.0, xs)\n")
    f = only(lint.scan_sources({"src/repro/x.py": src}), "R2")
    assert "`if` on traced value" in f.message


def test_r2_exemptions():
    # shape-derived ints are trace-time constants; `if` on attribute
    # access is static config branching; both must stay silent
    src = ("import jax\n"
           "\n"
           "def body(c, x):\n"
           "    n = int(x.shape[0])\n"
           "    if c.flag:\n"
           "        return c, x * n\n"
           "    return c, x\n"
           "\n"
           "def run(xs):\n"
           "    return jax.lax.scan(body, 0.0, xs)\n")
    assert lint.scan_sources({"src/repro/x.py": src}) == []


def test_r2_untraced_function_is_silent():
    src = ("def report(x):\n"
           "    return float(x)\n")
    assert lint.scan_sources({"src/repro/x.py": src}) == []


# ---------------------------------------------------------------- R3

def test_r3_read_after_jitcache_donation():
    src = ("def step(pool, fn, params, batch):\n"
           "    out = pool.call('run', fn, (1,), (params, batch))\n"
           "    return out, batch.sum()\n")
    f = only(lint.scan_sources({"src/repro/x.py": src}), "R3")
    assert "'batch'" in f.message and f.line == 3


def test_r3_rebind_clears_donation():
    src = ("def step(pool, fn, params, batch):\n"
           "    params = pool.call('run', fn, (0,), (params, batch))\n"
           "    return params\n")
    assert lint.scan_sources({"src/repro/x.py": src}) == []


def test_r3_donate_argnums():
    src = ("import jax\n"
           "\n"
           "def go(f, stack):\n"
           "    out = jax.jit(f, donate_argnums=(0,))(stack)\n"
           "    return out, stack\n")
    fs = lint.scan_sources({"src/repro/x.py": src})
    f = only([x for x in fs if x.rule == "R3"], "R3")
    assert "'stack'" in f.message


# ---------------------------------------------------------------- R4

def test_r4_orphan_kernel():
    files = {
        "src/repro/kernels/deadop.py": ("def dead_kernel(x):\n"
                                        "    return x\n"),
        "src/repro/core/user.py": "def use():\n    return 1\n",
    }
    f = only(lint.scan_sources(files), "R4")
    assert "deadop.dead_kernel" in f.message
    assert f.key == "deadop.dead_kernel"


def test_r4_referenced_kernel_is_alive():
    files = {
        "src/repro/kernels/op.py": "def my_kernel(x):\n    return x\n",
        "src/repro/core/user.py": ("from repro.kernels.op import "
                                   "my_kernel\n"
                                   "def use(x):\n"
                                   "    return my_kernel(x)\n"),
    }
    assert lint.scan_sources(files) == []


# ---------------------------------------------------------------- R5

def test_r5_bare_assert():
    src = ("def f(x):\n"
           "    assert x > 0, 'positive'\n"
           "    return x\n")
    f = only(lint.scan_sources({"src/repro/x.py": src}), "R5")
    assert "python -O" in f.message and f.line == 2


# ------------------------------------------------------- suppression

def test_suppression_same_line_and_preceding_line():
    src = ("def f(x):\n"
           "    assert x > 0  # repro-lint: disable=R5\n"
           "    # repro-lint: disable=R5\n"
           "    assert x < 9\n"
           "    return x\n")
    assert lint.scan_sources({"src/repro/x.py": src}) == []


def test_suppression_is_rule_specific():
    src = ("def f(x):\n"
           "    assert x > 0  # repro-lint: disable=R1\n"
           "    return x\n")
    only(lint.scan_sources({"src/repro/x.py": src}), "R5")


def test_suppression_disable_all():
    src = ("def f(x):\n"
           "    assert x > 0  # repro-lint: disable=all\n"
           "    return x\n")
    assert lint.scan_sources({"src/repro/x.py": src}) == []


# ---------------------------------------------------------- baseline

def test_baseline_roundtrip_and_determinism(tmp_path):
    src = {"src/repro/x.py": ("def f(x):\n"
                              "    assert x > 0\n"
                              "    assert x < 9\n"
                              "    return x\n")}
    findings = lint.scan_sources(src)
    assert len(findings) == 2
    text = lint.make_baseline(findings)
    assert text == lint.make_baseline(list(reversed(findings)))
    bp = tmp_path / "b.json"
    bp.write_text(text)
    new = lint.mark_baselined(lint.scan_sources(src),
                              lint.load_baseline(bp))
    assert new == []


def test_baseline_key_survives_line_moves(tmp_path):
    before = {"src/repro/x.py": "def f(x):\n    assert x > 0\n"}
    bp = tmp_path / "b.json"
    bp.write_text(lint.make_baseline(lint.scan_sources(before)))
    # same finding, shifted three lines down: still baselined
    after = {"src/repro/x.py": ("import os\n"
                                "\n"
                                "\n"
                                "def f(x):\n"
                                "    assert x > 0\n")}
    new = lint.mark_baselined(lint.scan_sources(after),
                              lint.load_baseline(bp))
    assert new == []


def test_new_finding_not_in_baseline_is_flagged(tmp_path):
    bp = tmp_path / "b.json"
    bp.write_text(lint.make_baseline([]))
    findings = lint.scan_sources(
        {"src/repro/x.py": "def f(x):\n    assert x\n"})
    new = lint.mark_baselined(findings, lint.load_baseline(bp))
    assert len(new) == 1 and not new[0].baselined


# --------------------------------------------------------- live tree

def test_live_tree_has_zero_non_baselined_findings():
    findings = lint.scan_paths(ROOT)
    baseline = lint.load_baseline(ROOT / "tools" / "lint_baseline.json")
    new = lint.mark_baselined(findings, baseline)
    assert new == [], ("non-baselined lint findings:\n" + "\n".join(
        f"{f.path}:{f.line}: {f.rule} {f.message}" for f in new))


def test_live_tree_has_no_orphans():
    """PR 7 fused the once-orphaned Pallas kernels into serving decode and
    wired the dead registry entry points into the launch CLIs — R4 must
    stay empty on the live tree (a new kernel/registry public function
    needs a real caller before it merges)."""
    keys = {f.key for f in lint.scan_paths(ROOT) if f.rule == "R4"}
    assert keys == set(), keys


def test_cli_check_passes_on_tree():
    import subprocess
    import sys
    res = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "repro_lint.py"),
         "--check"], capture_output=True, text=True)
    assert res.returncode == 0, res.stdout + res.stderr


def test_baseline_is_empty_and_stays_empty():
    """PR 8 drained the last baselined debt (distill's direct jits moved
    onto the JitCache engines): the baseline is [] and the live tree is
    clean WITHOUT it. New code must never re-grow the baseline — fix or
    suppress-with-justification instead."""
    import json
    baseline = json.loads(
        (ROOT / "tools" / "lint_baseline.json").read_text())
    assert baseline["findings"] == [], (
        "lint_baseline.json grew again; fix the findings or suppress "
        "them inline with a justification:\n"
        f"{baseline['findings']}")
    # and the tree is clean against an EMPTY baseline, so the file is
    # now purely a ratchet, not a debt ledger
    assert lint.scan_paths(ROOT) == []
