"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the exact TPU program on CPU)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.kd_loss import kd_loss_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.swa_attention import swa_attention_pallas


# ---------------------------------------------------------------------------
# kd_loss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,V", [(8, 512), (37, 1000), (64, 4096), (3, 300)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("alpha", [0.0, 0.5, 1.0])
def test_kd_loss_sweep(R, V, dtype, alpha, rng):
    s = jnp.asarray(rng.standard_normal((R, V)), dtype)
    t = jnp.asarray(rng.standard_normal((R, V)), dtype)
    lab = jnp.asarray(rng.integers(0, V, R), jnp.int32)
    got = kd_loss_pallas(s, t, lab, alpha, interpret=True)
    want = ref.kd_loss_ref(s, t, lab, alpha)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * max(1.0, float(
                                   jnp.max(jnp.abs(want)))))


def test_kd_loss_jit_wrapper_means(rng):
    s = jnp.asarray(rng.standard_normal((4, 7, 128)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((4, 7, 128)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, 128, (4, 7)), jnp.int32)
    got = ops.kd_loss(s, t, lab, 0.3)
    want = jnp.mean(ref.kd_loss_ref(s.reshape(28, 128), t.reshape(28, 128),
                                    lab.reshape(28), 0.3))
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


# T -> 0+ blows the MSE term up by 1/T² (tolerance scales with it),
# T >> 1 squashes it to ~0; alpha 0/1 turn off the CE / KD term entirely
@pytest.mark.parametrize("temperature", [1e-3, 0.5, 1.0, 100.0])
@pytest.mark.parametrize("alpha", [0.0, 1.0])
def test_kd_loss_temperature_alpha_extremes(temperature, alpha, rng):
    R, V = 16, 384
    s = jnp.asarray(rng.standard_normal((R, V)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((R, V)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, V, R), jnp.int32)
    got = kd_loss_pallas(s, t, lab, alpha, temperature=temperature,
                         interpret=True)
    want = ref.kd_loss_ref(s, t, lab, alpha, temperature=temperature)
    scale = max(1.0, float(jnp.max(jnp.abs(want))))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5 * scale)
    if alpha == 1.0:
        # pure CE: temperature must be a strict no-op
        base = kd_loss_pallas(s, t, lab, 1.0, temperature=1.0,
                              interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_kd_loss_masked_rows_exact_noop(rng):
    """Padded rows are *bitwise* no-ops: garbage (NaN/Inf/huge) logits in
    masked rows must not perturb any valid row, and masked outputs are
    exactly zero — forward and backward."""
    R, V = 8, 256
    s = jnp.asarray(rng.standard_normal((R, V)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((R, V)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, V, R), jnp.int32)
    clean = kd_loss_pallas(s, t, lab, 0.5, interpret=True)

    garbage = jnp.stack([jnp.full((V,), jnp.nan, jnp.float32),
                         jnp.full((V,), jnp.inf, jnp.float32),
                         jnp.full((V,), 1e30, jnp.float32)])
    s_pad = jnp.concatenate([s, garbage])
    t_pad = jnp.concatenate([t, garbage])
    lab_pad = jnp.concatenate([lab, jnp.zeros((3,), jnp.int32)])
    valid = jnp.concatenate([jnp.ones((R,), jnp.float32),
                             jnp.zeros((3,), jnp.float32)])
    padded = kd_loss_pallas(s_pad, t_pad, lab_pad, 0.5, valid=valid,
                            interpret=True)
    assert np.array_equal(np.asarray(padded[:R]), np.asarray(clean))
    assert np.array_equal(np.asarray(padded[R:]), np.zeros(3, np.float32))

    # backward through the custom-vjp rows entry: masked rows get 0 grads
    from repro.kernels.kd_loss import kd_loss_rows

    def total(sp, tp):
        return jnp.sum(kd_loss_rows(sp, tp, lab_pad, 0.5, valid=valid))

    ds, dt_ = jax.grad(total, argnums=(0, 1))(s_pad, t_pad)
    assert np.array_equal(np.asarray(ds[R:]), np.zeros((3, V), np.float32))
    assert np.array_equal(np.asarray(dt_[R:]), np.zeros((3, V), np.float32))
    assert np.isfinite(np.asarray(ds[:R])).all()
    assert np.isfinite(np.asarray(dt_[:R])).all()


@pytest.mark.parametrize("alpha,temperature", [(0.0, 1.0), (1.0, 1.0),
                                               (0.3, 2.0), (0.5, 0.5)])
def test_kd_loss_rows_grad_matches_eager(alpha, temperature, rng):
    """The kernel's analytic custom-vjp backward == jax autodiff through
    the eager oracle (the property the distill engine's training relies
    on when kd_kernel='pallas')."""
    from repro.kernels.kd_loss import kd_loss_rows
    R, V = 12, 320
    s = jnp.asarray(rng.standard_normal((R, V)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((R, V)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, V, R), jnp.int32)
    w = jnp.asarray(rng.standard_normal(R), jnp.float32)   # mixed cotangent

    def f_kernel(sp, tp):
        return jnp.sum(w * kd_loss_rows(sp, tp, lab, alpha,
                                        temperature=temperature))

    def f_eager(sp, tp):
        return jnp.sum(w * ref.kd_loss_ref(sp, tp, lab, alpha,
                                           temperature=temperature))

    gk = jax.grad(f_kernel, argnums=(0, 1))(s, t)
    ge = jax.grad(f_eager, argnums=(0, 1))(s, t)
    scale = max(1.0, float(jnp.max(jnp.abs(ge[0]))))
    for a, b in zip(gk, ge):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5 * scale)


# ---------------------------------------------------------------------------
# swa_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,D,w", [(256, 64, 32), (256, 64, 100),
                                   (128, 128, 128), (512, 64, 200),
                                   (256, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_attention_sweep(S, D, w, dtype, rng):
    BH = 3
    q = jnp.asarray(rng.standard_normal((BH, S, D)) * 0.3, dtype)
    k = jnp.asarray(rng.standard_normal((BH, S, D)) * 0.3, dtype)
    v = jnp.asarray(rng.standard_normal((BH, S, D)), dtype)
    got = swa_attention_pallas(q, k, v, w, q_block=min(128, S),
                               k_block=min(128, S), interpret=True)
    want = ref.swa_attention_ref(q, k, v, w)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_swa_full_attention_equals_window_S(rng):
    BH, S, D = 2, 256, 64
    q = jnp.asarray(rng.standard_normal((BH, S, D)) * 0.2, jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, S, D)) * 0.2, jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, S, D)), jnp.float32)
    got = ops.swa_attention(q, k, v, window=0)       # 0 -> full causal
    want = ref.swa_attention_ref(q, k, v, S)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_swa_matches_model_attention(rng):
    """Kernel agrees with the model's jnp attention path (GQA folded)."""
    from repro.models.attention import gqa_attention
    B, S, H, D, w = 2, 128, 4, 64, 48
    q = jnp.asarray(rng.standard_normal((B, S, H, D)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, D)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    want = gqa_attention(q, k, v, window=w, q_chunk=64)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    got = ops.swa_attention(qf, kf, vf, window=w)
    got = got.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,H,P,N,chunk", [(128, 2, 32, 16, 32),
                                           (256, 3, 64, 16, 64),
                                           (256, 2, 32, 128, 128),
                                           (64, 1, 64, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(S, H, P, N, chunk, dtype, rng):
    B = 2
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), dtype)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((B, S, H)),
                                     jnp.float32))
    A = -jnp.exp(jnp.asarray(rng.standard_normal(H) * 0.3, jnp.float32))
    Bm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, dtype)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, dtype)
    yk, hk = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk, interpret=True)
    yr, hr = ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    scale = max(1.0, float(jnp.max(jnp.abs(yr))))
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=tol, atol=tol * scale)
    np.testing.assert_allclose(np.asarray(hk, np.float32),
                               np.asarray(hr, np.float32),
                               rtol=tol, atol=tol * scale)


def test_ssd_chunked_matches_sequential(rng):
    """The chunked algorithm (model + kernel oracle) vs the O(S) recurrence."""
    B, S, H, P, N = 2, 128, 2, 16, 8
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((B, S, H)),
                                     jnp.float32))
    A = -jnp.exp(jnp.asarray(rng.standard_normal(H) * 0.3, jnp.float32))
    Bm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
    yc, hc = ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk=32)
    ys, hs = ref.ssd_sequential_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(ys),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hs),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# fused decode kernels: ring attend / ladder-extent attend / SSD step
# (parity oracle = the PR-5 einsum decode path in models/attention, ssm)
# ---------------------------------------------------------------------------

from repro.kernels.ssd_scan import ssd_decode_step_pallas
from repro.kernels.swa_attention import (extent_decode_attend_pallas,
                                         ring_decode_attend_pallas)


def _ring_oracle(q, k, v, pos, window):
    """The einsum ring decode attend (gqa_attention + slot positions)."""
    from repro.models.attention import gqa_attention
    B, KV, G, D = q.shape
    W = k.shape[1]
    k_pos = pos - jnp.mod(pos - jnp.arange(W), W)
    out = gqa_attention(q.reshape(B, 1, KV * G, D), k, v, window=window,
                        causal=True, q_offset=pos, k_positions=k_pos,
                        q_chunk=1)
    return out.reshape(B, KV, G, D)


def _extent_oracle(q, k, v, pos, window, k_ext):
    """The einsum k_extent decode attend (slice + k_len mask)."""
    from repro.models.attention import gqa_attention
    B, KV, G, D = q.shape
    out = gqa_attention(q.reshape(B, 1, KV * G, D),
                        k[:, :k_ext], v[:, :k_ext], window=window,
                        causal=True, q_offset=pos, k_len=pos + 1, q_chunk=1)
    return out.reshape(B, KV, G, D)


# odd windows, window 0 (full), W = 1, pos < W (short prompt) and pos >> W
@pytest.mark.parametrize("W,pos,window", [
    (16, 5, 7),        # pos < W: unwritten slots must be masked
    (16, 40, 7),       # wrapped ring, odd window
    (16, 40, 13),      # odd window > half the ring
    (16, 3, 0),        # full attention over a partially written ring
    (1, 0, 1),         # W = 1 edge: only the current token
    (1, 25, 1),
    (17, 33, 17),      # odd ring capacity
])
def test_ring_decode_attend_parity(W, pos, window, rng):
    B, KV, G, D = 3, 2, 3, 16
    q = jnp.asarray(rng.standard_normal((B, KV, G, D)) * 0.4, jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, W, KV, D)) * 0.4, jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, W, KV, D)), jnp.float32)
    got = ring_decode_attend_pallas(q, k, v, jnp.int32(pos),
                                    jnp.int32(window), interpret=True)
    want = _ring_oracle(q, k, v, jnp.int32(pos), jnp.int32(window))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# k_ext at every rung of the pow-2 ladder (min_bucket 4 .. S_max 64)
@pytest.mark.parametrize("k_ext", [4, 8, 16, 32, 64])
@pytest.mark.parametrize("window", [0, 5])
def test_extent_decode_attend_ladder_parity(k_ext, window, rng):
    B, KV, G, D, S_max = 2, 2, 2, 16, 64
    pos = k_ext - 1                    # deepest position the rung serves
    q = jnp.asarray(rng.standard_normal((B, KV, G, D)) * 0.4, jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S_max, KV, D)) * 0.4,
                    jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S_max, KV, D)), jnp.float32)
    got = extent_decode_attend_pallas(q, k, v, jnp.int32(pos),
                                      jnp.int32(window), k_ext,
                                      interpret=True)
    want = _extent_oracle(q, k, v, jnp.int32(pos), jnp.int32(window), k_ext)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # shallow position on the same rung: pad slots are k_len-masked
    got0 = extent_decode_attend_pallas(q, k, v, jnp.int32(0),
                                       jnp.int32(window), k_ext,
                                       interpret=True)
    want0 = _extent_oracle(q, k, v, jnp.int32(0), jnp.int32(window), k_ext)
    np.testing.assert_allclose(np.asarray(got0), np.asarray(want0),
                               rtol=1e-5, atol=1e-5)


def test_extent_decode_attend_rejects_bad_extent(rng):
    q = jnp.zeros((1, 1, 1, 8), jnp.float32)
    k = jnp.zeros((1, 16, 1, 8), jnp.float32)
    with pytest.raises(ValueError):
        extent_decode_attend_pallas(q, k, k, jnp.int32(0), jnp.int32(0), 0)
    with pytest.raises(ValueError):
        extent_decode_attend_pallas(q, k, k, jnp.int32(0), jnp.int32(0), 17)


def test_ssd_decode_step_parity(rng):
    """Fused step == the dA/upd/state/y einsum block, including dt=0
    rows (ladder pad steps) being exact state no-ops."""
    B, H, P, N = 3, 4, 8, 16
    xh = jnp.asarray(rng.standard_normal((B, H, P)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((B, H)),
                                     jnp.float32))
    dt = dt.at[1].set(0.0)            # pad-row: exact no-op on the state
    A = -jnp.exp(jnp.asarray(rng.standard_normal(H) * 0.3, jnp.float32))
    Bm = jnp.asarray(rng.standard_normal((B, N)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, N)) * 0.5, jnp.float32)
    st = jnp.asarray(rng.standard_normal((B, H, P, N)), jnp.float32)

    dA = jnp.exp(dt * A[None, :])
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt.astype(xh.dtype), xh, Bm)
    st_want = st * dA[..., None, None].astype(st.dtype) + upd
    y_want = jnp.einsum("bhpn,bn->bhp", st_want, Cm)

    y_got, st_got = ssd_decode_step_pallas(xh, dt, A, Bm, Cm, st,
                                           interpret=True)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_got), np.asarray(st_want),
                               rtol=1e-5, atol=1e-5)
    # the dt=0 row's state is untouched bit-for-bit
    assert bool(jnp.all(st_got[1] == st[1]))


def test_ssd_decode_step_multi_step_vs_sequential(rng):
    """Iterating the fused step tracks the O(S) sequential reference."""
    B, S, H, P, N = 2, 24, 2, 8, 8
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((B, S, H)),
                                     jnp.float32))
    A = -jnp.exp(jnp.asarray(rng.standard_normal(H) * 0.3, jnp.float32))
    Bm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
    ys_ref, h_ref = ops.ssd_sequential_ref(x, dt, A, Bm, Cm)
    h = jnp.zeros((B, H, P, N), jnp.float32)
    ys = []
    for t in range(S):
        y, h = ssd_decode_step_pallas(x[:, t], dt[:, t], A, Bm[:, t],
                                      Cm[:, t], h, interpret=True)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(ys_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# family-level fused-vs-einsum decode parity (all five LM families)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["h2o-danube-3-4b", "gemma3-12b",
                                  "llama4-scout-17b-a16e", "mamba2-130m",
                                  "hymba-1.5b"])
def test_decode_step_grouped_kernel_parity(arch, rng):
    """One fused decode step == one einsum decode step — same logits to
    fp32 tolerance and the same greedy token, from the same prefilled
    ring cache, for every LM family."""
    from repro.configs import get_config
    from repro.models import lm, registry
    cfg = get_config(arch).reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    B, S_max, P = 2, 32, 9
    cache = registry.init_cache(cfg, B, S_max, jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32)
    logits, cache = registry.prefill(params, cfg, {"tokens": toks}, cache,
                                     q_chunk=P)
    ring = lm.to_ring_cache(cfg, cache, P)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = {}
    for kern in ("einsum", "pallas"):
        outs[kern] = registry.decode_step_grouped(
            params, cfg, tok, dict(ring), jnp.int32(P), k_ext=16,
            decode_kernel=kern)
    lg_e, lg_p = outs["einsum"][0], outs["pallas"][0]
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_e),
                               rtol=2e-5, atol=2e-5)
    assert jnp.array_equal(jnp.argmax(lg_p, -1), jnp.argmax(lg_e, -1))
    for key in outs["einsum"][1]:
        np.testing.assert_allclose(
            np.asarray(outs["pallas"][1][key], np.float32),
            np.asarray(outs["einsum"][1][key], np.float32),
            rtol=2e-5, atol=2e-5, err_msg=key)
