"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the exact TPU program on CPU)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.kd_loss import kd_loss_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas
from repro.kernels.swa_attention import swa_attention_pallas


# ---------------------------------------------------------------------------
# kd_loss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,V", [(8, 512), (37, 1000), (64, 4096), (3, 300)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("alpha", [0.0, 0.5, 1.0])
def test_kd_loss_sweep(R, V, dtype, alpha, rng):
    s = jnp.asarray(rng.standard_normal((R, V)), dtype)
    t = jnp.asarray(rng.standard_normal((R, V)), dtype)
    lab = jnp.asarray(rng.integers(0, V, R), jnp.int32)
    got = kd_loss_pallas(s, t, lab, alpha, interpret=True)
    want = ref.kd_loss_ref(s, t, lab, alpha)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * max(1.0, float(
                                   jnp.max(jnp.abs(want)))))


def test_kd_loss_jit_wrapper_means(rng):
    s = jnp.asarray(rng.standard_normal((4, 7, 128)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((4, 7, 128)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, 128, (4, 7)), jnp.int32)
    got = ops.kd_loss(s, t, lab, 0.3)
    want = jnp.mean(ref.kd_loss_ref(s.reshape(28, 128), t.reshape(28, 128),
                                    lab.reshape(28), 0.3))
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# swa_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,D,w", [(256, 64, 32), (256, 64, 100),
                                   (128, 128, 128), (512, 64, 200),
                                   (256, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_attention_sweep(S, D, w, dtype, rng):
    BH = 3
    q = jnp.asarray(rng.standard_normal((BH, S, D)) * 0.3, dtype)
    k = jnp.asarray(rng.standard_normal((BH, S, D)) * 0.3, dtype)
    v = jnp.asarray(rng.standard_normal((BH, S, D)), dtype)
    got = swa_attention_pallas(q, k, v, w, q_block=min(128, S),
                               k_block=min(128, S), interpret=True)
    want = ref.swa_attention_ref(q, k, v, w)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_swa_full_attention_equals_window_S(rng):
    BH, S, D = 2, 256, 64
    q = jnp.asarray(rng.standard_normal((BH, S, D)) * 0.2, jnp.float32)
    k = jnp.asarray(rng.standard_normal((BH, S, D)) * 0.2, jnp.float32)
    v = jnp.asarray(rng.standard_normal((BH, S, D)), jnp.float32)
    got = ops.swa_attention(q, k, v, window=0)       # 0 -> full causal
    want = ref.swa_attention_ref(q, k, v, S)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_swa_matches_model_attention(rng):
    """Kernel agrees with the model's jnp attention path (GQA folded)."""
    from repro.models.attention import gqa_attention
    B, S, H, D, w = 2, 128, 4, 64, 48
    q = jnp.asarray(rng.standard_normal((B, S, H, D)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, D)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    want = gqa_attention(q, k, v, window=w, q_chunk=64)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    got = ops.swa_attention(qf, kf, vf, window=w)
    got = got.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,H,P,N,chunk", [(128, 2, 32, 16, 32),
                                           (256, 3, 64, 16, 64),
                                           (256, 2, 32, 128, 128),
                                           (64, 1, 64, 64, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(S, H, P, N, chunk, dtype, rng):
    B = 2
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), dtype)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((B, S, H)),
                                     jnp.float32))
    A = -jnp.exp(jnp.asarray(rng.standard_normal(H) * 0.3, jnp.float32))
    Bm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, dtype)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, dtype)
    yk, hk = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk, interpret=True)
    yr, hr = ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    scale = max(1.0, float(jnp.max(jnp.abs(yr))))
    np.testing.assert_allclose(np.asarray(yk, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=tol, atol=tol * scale)
    np.testing.assert_allclose(np.asarray(hk, np.float32),
                               np.asarray(hr, np.float32),
                               rtol=tol, atol=tol * scale)


def test_ssd_chunked_matches_sequential(rng):
    """The chunked algorithm (model + kernel oracle) vs the O(S) recurrence."""
    B, S, H, P, N = 2, 128, 2, 16, 8
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((B, S, H)),
                                     jnp.float32))
    A = -jnp.exp(jnp.asarray(rng.standard_normal(H) * 0.3, jnp.float32))
    Bm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
    yc, hc = ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk=32)
    ys, hs = ref.ssd_sequential_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(ys),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hs),
                               rtol=1e-3, atol=1e-3)
