"""Pluggable FedAlgorithm layer (core/algorithms.py) vs the engines.

Three contracts, per docs/algorithms.md:
  1. FedProx through the algorithm layer is BIT-identical to the
     pre-refactor default paths (empty state/ctx/msg pytrees -> the same
     traced programs).
  2. Stateful algorithms (SCAFFOLD, low-rank submodels) agree between the
     batched engines (vmap / padded masked-scan / shard_map / hierarchical
     / async scan) and the per-iteration loop oracle, including the
     per-client state and server context they persist across rounds.
  3. The low-rank/masked-submodel codec shrinks the wire vs the dense
     delta at matched quantization width.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import algorithms, compression, fed_engine, fedavg, simulator
from repro.core.algorithms import (FedProx, LowRankSubmodel, Scaffold,
                                   make_algorithm)
from repro.core.fleet import Fleet, JETSON_FLEET_HMDB51
from repro.data import BatchLoader, SyntheticLMDataset
from repro.models import registry
from repro.types import FedConfig, ModelConfig

TINY = ModelConfig(name="alg-test-tiny", family="dense", num_layers=1,
                   d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                   vocab_size=64)


def tree_allclose(a, b, rtol=1e-5, atol=1e-5):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)


def tree_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture(scope="module")
def setup():
    params = registry.init_params(jax.random.PRNGKey(0), TINY)
    fed = FedConfig(num_clients=3, global_epochs=4, local_iters_min=1,
                    local_iters_max=3, lr=0.01)
    ds = SyntheticLMDataset(vocab=TINY.vocab_size, seq_len=8, seed=0)
    return params, fed, ds


def client_lists(ds, fed, n, Hs=None, seed0=0):
    Hs = Hs or [fed.local_iters_max] * n
    return [list(ds.batches(2, h, seed=seed0 + k))
            for k, h in enumerate(Hs)]


# ---------------------------------------------------------------------------
# The algorithm knob
# ---------------------------------------------------------------------------

def test_make_algorithm_validates():
    assert isinstance(make_algorithm("scaffold"), Scaffold)
    assert isinstance(make_algorithm("fedprox"), FedProx)
    alg = LowRankSubmodel()
    assert make_algorithm(alg) is alg          # instances pass through
    with pytest.raises(ValueError) as e:
        make_algorithm("fedavgm")
    for name in sorted(algorithms.ALGORITHMS):  # error names the options
        assert name in str(e.value)


def test_fedprox_explicit_is_bit_identical(setup):
    """algorithm=FedProx() and algorithm=None must run the SAME traced
    program: empty state pytrees add zero traced leaves."""
    params, fed, ds = setup
    bl = client_lists(ds, fed, 3)
    g_default, l_default = fedavg.fedavg_round(
        params, [iter(b) for b in bl], TINY, fed)
    g_alg, l_alg = fedavg.fedavg_round(
        params, [iter(b) for b in bl], TINY, fed, algorithm=FedProx())
    tree_equal(g_default, g_alg)
    np.testing.assert_array_equal(l_default, l_alg)


# ---------------------------------------------------------------------------
# SCAFFOLD: engines vs the loop oracle, state persistence
# ---------------------------------------------------------------------------

def test_scaffold_round_matches_loop(setup):
    params, fed, ds = setup
    n = 3
    alg_loop, alg_eng = Scaffold(), Scaffold()
    g = {"loop": params, "eng": params}
    for rnd in range(2):              # 2 rounds: state must thread through
        bl = client_lists(ds, fed, n, seed0=10 * rnd)
        g["loop"], l_loop = fedavg.fedavg_round_loop(
            g["loop"], [iter(b) for b in bl], TINY, fed,
            algorithm=alg_loop)
        g["eng"], l_eng = fedavg.fedavg_round(
            g["eng"], [iter(b) for b in bl], TINY, fed, algorithm=alg_eng)
        tree_allclose(g["loop"], g["eng"], rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(
            np.concatenate([np.asarray(l) for l in l_eng]),
            np.concatenate([np.asarray(l) for l in l_loop]), rtol=1e-4)
    # both instances persisted the same server variate and client variates
    tree_allclose(alg_loop.ctx_for(params), alg_eng.ctx_for(params),
                  rtol=1e-4, atol=1e-5)
    for k in range(n):
        tree_allclose(alg_loop.state_for(k, params),
                      alg_eng.state_for(k, params), rtol=1e-4, atol=1e-5)
    # the control variates actually moved (a zero variate would also pass
    # the parity checks above)
    moved = sum(float(jnp.sum(jnp.abs(l))) for l in
                jax.tree_util.tree_leaves(alg_eng.state_for(0, params)))
    assert moved > 0


def test_scaffold_padded_ragged_matches_loop(setup):
    """Heterogeneous H^k batch through the padded masked-scan program."""
    params, fed, ds = setup
    Hs = [3, 1, 2]
    alg_loop, alg_eng = Scaffold(), Scaffold()
    bl = client_lists(ds, fed, 3, Hs=Hs, seed0=40)
    g_loop, l_loop = fedavg.fedavg_round_loop(
        params, [iter(b) for b in bl], TINY, fed, algorithm=alg_loop)
    g_eng, l_eng = fedavg.fedavg_round(
        params, [iter(b) for b in bl], TINY, fed, algorithm=alg_eng)
    assert [len(l) for l in l_eng] == Hs
    tree_allclose(g_loop, g_eng, rtol=1e-4, atol=1e-5)
    for k in range(3):
        tree_allclose(alg_loop.state_for(k, params),
                      alg_eng.state_for(k, params), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("engine", ["shard", "hier"])
def test_scaffold_sharded_engines_match_vmap(setup, engine):
    """The shard_map'ed round (single-device mesh here) folds the variate
    deltas with a psum; it must agree with the plain vmap round."""
    params, fed, ds = setup
    bl = client_lists(ds, fed, 4, seed0=70)
    alg_ref, alg_sh = Scaffold(), Scaffold()
    g_ref, _ = fedavg.fedavg_round(
        params, [iter(b) for b in bl], TINY, fed, algorithm=alg_ref)
    g_sh, _ = fedavg.fedavg_round(
        params, [iter(b) for b in bl], TINY, fed, engine=engine,
        algorithm=alg_sh)
    tree_allclose(g_ref, g_sh, rtol=1e-4, atol=1e-5)
    tree_allclose(alg_ref.ctx_for(params), alg_sh.ctx_for(params),
                  rtol=1e-4, atol=1e-5)


def make_fleet(ds, n=3):
    return Fleet.from_lists(
        list(JETSON_FLEET_HMDB51)[:n],
        [BatchLoader(ds, 2, steps=4, seed=k) for k in range(n)])


def test_async_scaffold_scan_matches_loop(setup):
    """Algorithm 1 with SCAFFOLD: the variate delta rides the staleness-
    damped server mix identically on both client engines."""
    params, fed, ds = setup
    outs = {}
    for eng in ("scan", "loop"):
        res = simulator.run_async(params, TINY, fed, make_fleet(ds),
                                  engine=eng, algorithm=Scaffold())
        outs[eng] = res
    tree_allclose(outs["scan"].params, outs["loop"].params,
                  rtol=1e-4, atol=1e-5)
    assert outs["scan"].staleness_hist == outs["loop"].staleness_hist


def test_async_fedprox_explicit_is_bit_identical(setup):
    params, fed, ds = setup
    r_default = simulator.run_async(params, TINY, fed, make_fleet(ds))
    r_alg = simulator.run_async(params, TINY, fed, make_fleet(ds),
                                algorithm=FedProx())
    tree_equal(r_default.params, r_alg.params)
    assert r_default.final_loss == r_alg.final_loss


def test_sync_simulator_scaffold_runs(setup):
    params, fed, ds = setup
    res = simulator.run_sync(params, TINY, fed, make_fleet(ds),
                             algorithm=Scaffold())
    assert np.isfinite(res.final_loss)


# ---------------------------------------------------------------------------
# Low-rank / masked submodels
# ---------------------------------------------------------------------------

def test_lowrank_round_matches_loop(setup):
    params, fed, ds = setup
    bl = client_lists(ds, fed, 3, seed0=90)
    alg_loop, alg_eng = LowRankSubmodel(), LowRankSubmodel()
    g_loop, _ = fedavg.fedavg_round_loop(
        params, [iter(b) for b in bl], TINY, fed, algorithm=alg_loop)
    g_eng, _ = fedavg.fedavg_round(
        params, [iter(b) for b in bl], TINY, fed, algorithm=alg_eng)
    tree_allclose(g_loop, g_eng, rtol=1e-4, atol=1e-4)


def test_async_lowrank_scan_matches_loop(setup):
    params, fed, ds = setup
    outs = {}
    for eng in ("scan", "loop"):
        res = simulator.run_async(params, TINY, fed, make_fleet(ds),
                                  engine=eng, algorithm=LowRankSubmodel())
        outs[eng] = res
    tree_allclose(outs["scan"].params, outs["loop"].params,
                  rtol=1e-4, atol=1e-4)


def test_lowrank_capacity_follows_fleet_speed(setup):
    params, fed, ds = setup
    alg = LowRankSubmodel()
    fleet = make_fleet(ds, n=4)
    alg.bind_fleet(fleet)
    caps = [alg.capacity_for(k) for k in range(4)]
    assert all(0.0 < c <= 1.0 for c in caps)
    # the fastest device (smallest epoch time) keeps the largest submodel
    times = [fleet.profile(k).epoch_seconds for k in range(4)]
    assert caps[int(np.argmin(times))] == max(caps)
    assert caps[int(np.argmax(times))] == min(caps)


def test_lowrank_wire_beats_dense_at_matched_bits(setup):
    """The acceptance claim: at matched quantization width the truncated
    factors ship fewer bytes per round than the dense int8 delta."""
    params, fed, ds = setup
    fed8 = dataclasses.replace(fed, compress_bits=8)
    alg = LowRankSubmodel()
    w_new, state, msg, _ = algorithms.client_update_loop(
        params, client_lists(ds, fed, 1, seed0=5)[0], TINY, fed8, alg,
        server_ctx=alg.ctx_for(params))
    wire8 = alg.encode(w_new, msg, params, fed8)
    dense8 = compression.quantize_delta(w_new, params, 8)
    assert wire8.wire_bytes < dense8.wire_bytes
    # int4 halves the packed payload again
    fed4 = dataclasses.replace(fed, compress_bits=4)
    wire4 = alg.encode(w_new, msg, params, fed4)
    assert wire4.wire_bytes < wire8.wire_bytes
    # decode reconstructs the anchor's tree structure with finite leaves
    w_dec, _ = alg.decode(wire8, params, fed8)
    assert (jax.tree_util.tree_structure(w_dec)
            == jax.tree_util.tree_structure(params))
    for leaf in jax.tree_util.tree_leaves(w_dec):
        assert np.all(np.isfinite(np.asarray(leaf)))


# ---------------------------------------------------------------------------
# Convergence smoke: SCAFFOLD vs FedProx on a non-IID fleet
# ---------------------------------------------------------------------------

def test_scaffold_at_least_fedprox_noniid():
    """On a Dirichlet label-skewed fleet the control variates correct the
    client drift: held-out accuracy must not fall below plain FedProx."""
    from repro.configs import RESNET18
    from repro.data import SyntheticActionDataset, dirichlet_partition
    cfg = RESNET18.reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticActionDataset(num_classes=8, samples_per_class=8, seed=1)
    labels = np.arange(len(ds)) % 8
    parts = dirichlet_partition(labels, 4, alpha=0.1, seed=3)
    fed = FedConfig(num_clients=4, global_epochs=16, local_iters_min=4,
                    local_iters_max=4, lr=0.01, prox_theta=0.0, seed=0)

    def fleet():
        return Fleet.from_lists(
            list(JETSON_FLEET_HMDB51),
            [BatchLoader(ds, 4, steps=4, seed=k, indices=parts[k])
             for k in range(4)])

    held_out = list(ds.batches(8, 4, seed=999))

    def accuracy(p):
        hits = total = 0
        for b in held_out:
            logits = registry.logits_fn(p, cfg, b)
            hits += int(np.sum(np.argmax(np.asarray(logits), -1)
                               == b["labels"]))
            total += len(b["labels"])
        return hits / total

    accs = {}
    for name in ("fedprox", "scaffold"):
        res = simulator.run_sync(params, cfg, fed, fleet(),
                                 algorithm=make_algorithm(name))
        accs[name] = accuracy(res.params)
    assert accs["scaffold"] >= accs["fedprox"]
