"""End-to-end behaviour tests for the paper's system: the two-stage pipeline
(server-side KD, then federated fine-tuning) and the paper's headline claims
at smoke scale."""
import numpy as np
import pytest

import jax

from repro.configs import RESNET18, RESNET34, get_config
from repro.core import distill, simulator
from repro.core.simulator import JETSON_FLEET_HMDB51
from repro.data import BatchLoader, SyntheticActionDataset, iid_partition
from repro.models import registry
from repro.types import DistillConfig, FedConfig


@pytest.mark.slow
def test_full_pipeline_kd_then_async_fl():
    """Stage 1: distill teacher->student on the 'large' dataset at the
    server. Stage 2: fine-tune the student on the 'small' dataset across a
    heterogeneous fleet with Algorithm 1. Loss decreases at both stages and
    async wall-clock beats sync."""
    t_cfg, s_cfg = RESNET34.reduced(), RESNET18.reduced()

    big = SyntheticActionDataset(num_classes=8, samples_per_class=32,
                                 noise=0.3, seed=0)
    loader = BatchLoader(big, 8, steps=10, seed=0)
    eval_b = list(big.batches(8, 3, seed=99))
    dcfg = DistillConfig(alpha=0.5, lr=0.02)
    student, stages = distill.run_chain(
        [t_cfg, s_cfg], dcfg, loader, eval_b, steps_per_stage=10,
        seed=0, trained_teacher_steps=10)
    assert stages[0].losses[-1] < stages[0].losses[0]

    small = SyntheticActionDataset(num_classes=8, samples_per_class=8,
                                   noise=0.5, seed=5)
    fed = FedConfig(num_clients=4, global_epochs=8, local_iters_min=1,
                    local_iters_max=2, lr=0.02, trainable="all")
    parts = iid_partition(len(small), 4)
    data = [BatchLoader(small, 4, steps=4, seed=k, indices=parts[k])
            for k in range(4)]
    res_async = simulator.run_async(student, s_cfg, fed,
                                    JETSON_FLEET_HMDB51, data)
    res_sync = simulator.run_sync(student, s_cfg, fed,
                                  JETSON_FLEET_HMDB51, data)
    assert res_async.wall_clock_s < res_sync.wall_clock_s
    assert np.isfinite(res_async.final_loss)


@pytest.mark.slow
def test_pipeline_driver_kd_transfer_beats_scratch_init():
    """launch/pipeline.py end-to-end: tiny resnet3d teacher pretrains on
    the server's 'large' dataset, KD-compresses into the student, and the
    student fine-tunes across the 4-client heterogeneous fleet. The
    KD-initialized student must beat the same fine-tune from an
    undistilled (random) init on BOTH held-out accuracy and final loss —
    the paper's reason stage 1 exists."""
    from repro.launch.pipeline import run_pipeline
    report, _ = run_pipeline(
        reduced=True, mode="sync", clients=4, epochs=3, batch=8,
        kd_steps=64, teacher_steps=96, kd_lr=0.05, kd_epoch_len=32,
        eval_steps=4, seed=0, compare_scratch=True)
    st1 = report["stage1"]["stages"][0]
    assert st1["accuracy"] > 0.3          # stage 1 actually distilled
    assert report["stage2"]["accuracy"] > report["scratch"]["accuracy"]
    assert report["stage2"]["final_loss"] < report["scratch"]["final_loss"]


@pytest.mark.slow
def test_pipeline_driver_bit_reproducible_and_loop_parity():
    """The KD -> fine-tune pipeline is bit-reproducible under a fixed
    seed (identical param digests across runs), and the compiled scan
    engine's fine-tune matches the legacy per-client loop engine."""
    from repro.launch.pipeline import run_pipeline
    kw = dict(reduced=True, mode="sync", clients=2, epochs=2, batch=2,
              kd_steps=4, teacher_steps=2, eval_steps=2, seed=0)
    r1, p1 = run_pipeline(**kw)
    r2, _ = run_pipeline(**kw)
    assert r1["params_digest"] == r2["params_digest"]
    assert r1["stage1"]["digest"] == r2["stage1"]["digest"]
    r3, p3 = run_pipeline(engine="loop", **kw)
    assert r1["stage1"]["digest"] == r3["stage1"]["digest"]
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p3)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_train_driver_central_mode(capsys):
    from repro.launch import train as train_mod
    rc = train_mod.main(["--arch", "mamba2-130m", "--reduced",
                         "--mode", "central", "--steps", "6",
                         "--batch", "2"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "final_loss" in out


@pytest.mark.slow
def test_train_driver_async_mode(capsys):
    from repro.launch import train as train_mod
    rc = train_mod.main(["--arch", "resnet3d-18", "--reduced",
                         "--mode", "async", "--epochs", "6",
                         "--batch", "2", "--clients", "4"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "staleness histogram" in out


@pytest.mark.slow
def test_serve_driver(capsys):
    from repro.launch import serve as serve_mod
    rc = serve_mod.main(["--arch", "h2o-danube-3-4b", "--reduced",
                         "--batch", "2", "--prompt-len", "16",
                         "--gen", "4"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "decode:" in out


def test_dryrun_list_matrix():
    """The dry-run matrix declaration (no compiles): 34 RUN + 6 SKIP."""
    import subprocess, sys, os
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--list"],
        capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0
    lines = [l for l in r.stdout.splitlines() if l.strip()]
    runs = sum(1 for l in lines if " RUN" in l)
    skips = sum(1 for l in lines if "SKIP" in l)
    assert runs == 34 and skips == 6
