"""End-to-end behaviour tests for the paper's system: the two-stage pipeline
(server-side KD, then federated fine-tuning) and the paper's headline claims
at smoke scale."""
import numpy as np
import pytest

import jax

from repro.configs import RESNET18, RESNET34, get_config
from repro.core import distill, simulator
from repro.core.simulator import JETSON_FLEET_HMDB51
from repro.data import BatchLoader, SyntheticActionDataset, iid_partition
from repro.models import registry
from repro.types import DistillConfig, FedConfig


@pytest.mark.slow
def test_full_pipeline_kd_then_async_fl():
    """Stage 1: distill teacher->student on the 'large' dataset at the
    server. Stage 2: fine-tune the student on the 'small' dataset across a
    heterogeneous fleet with Algorithm 1. Loss decreases at both stages and
    async wall-clock beats sync."""
    t_cfg, s_cfg = RESNET34.reduced(), RESNET18.reduced()

    big = SyntheticActionDataset(num_classes=8, samples_per_class=32,
                                 noise=0.3, seed=0)
    loader = BatchLoader(big, 8, steps=10, seed=0)
    eval_b = list(big.batches(8, 3, seed=99))
    dcfg = DistillConfig(alpha=0.5, lr=0.02)
    student, stages = distill.run_chain(
        [t_cfg, s_cfg], dcfg, loader, eval_b, steps_per_stage=10,
        seed=0, trained_teacher_steps=10)
    assert stages[0].losses[-1] < stages[0].losses[0]

    small = SyntheticActionDataset(num_classes=8, samples_per_class=8,
                                   noise=0.5, seed=5)
    fed = FedConfig(num_clients=4, global_epochs=8, local_iters_min=1,
                    local_iters_max=2, lr=0.02, trainable="all")
    parts = iid_partition(len(small), 4)
    data = [BatchLoader(small, 4, steps=4, seed=k, indices=parts[k])
            for k in range(4)]
    res_async = simulator.run_async(student, s_cfg, fed,
                                    JETSON_FLEET_HMDB51, data)
    res_sync = simulator.run_sync(student, s_cfg, fed,
                                  JETSON_FLEET_HMDB51, data)
    assert res_async.wall_clock_s < res_sync.wall_clock_s
    assert np.isfinite(res_async.final_loss)


@pytest.mark.slow
def test_train_driver_central_mode(capsys):
    from repro.launch import train as train_mod
    rc = train_mod.main(["--arch", "mamba2-130m", "--reduced",
                         "--mode", "central", "--steps", "6",
                         "--batch", "2"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "final_loss" in out


@pytest.mark.slow
def test_train_driver_async_mode(capsys):
    from repro.launch import train as train_mod
    rc = train_mod.main(["--arch", "resnet3d-18", "--reduced",
                         "--mode", "async", "--epochs", "6",
                         "--batch", "2", "--clients", "4"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "staleness histogram" in out


@pytest.mark.slow
def test_serve_driver(capsys):
    from repro.launch import serve as serve_mod
    rc = serve_mod.main(["--arch", "h2o-danube-3-4b", "--reduced",
                         "--batch", "2", "--prompt-len", "16",
                         "--gen", "4"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "decode:" in out


def test_dryrun_list_matrix():
    """The dry-run matrix declaration (no compiles): 34 RUN + 6 SKIP."""
    import subprocess, sys, os
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--list"],
        capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0
    lines = [l for l in r.stdout.splitlines() if l.strip()]
    runs = sum(1 for l in lines if " RUN" in l)
    skips = sum(1 for l in lines if "SKIP" in l)
    assert runs == 34 and skips == 6
