"""Asynchronous federated optimization core (paper Algorithm 1) + FedAvg."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import convergence, fedasync, fedavg
from repro.core.fedasync import ServerState, server_receive, staleness_fn
from repro.types import FedConfig


def test_staleness_function():
    s = staleness_fn(0.5)
    assert float(s(0)) == 1.0                       # s(0) = 1
    vals = [float(s(x)) for x in range(6)]
    assert all(a > b for a, b in zip(vals, vals[1:]))   # monotone decreasing
    np.testing.assert_allclose(float(s(3)), (1 + 3) ** -0.5)
    # a=0 -> no staleness penalty
    s0 = staleness_fn(0.0)
    assert all(float(s0(x)) == 1.0 for x in range(5))


def test_server_mixing_update():
    fed = FedConfig(mixing_beta=0.7, staleness_a=0.5)
    w = {"a": jnp.zeros(3), "b": jnp.ones(2)}
    w_new = {"a": jnp.ones(3), "b": jnp.zeros(2)}
    st = ServerState(params=w, t=0)
    st2 = server_receive(st, w_new, tau=0, fed=fed)
    # staleness 0 -> beta_t = 0.7
    np.testing.assert_allclose(np.asarray(st2.params["a"]), 0.7, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(st2.params["b"]), 0.3, rtol=1e-6)
    assert st2.t == 1

    # stale update gets down-weighted: beta_t = 0.7 * (1+4)^-0.5
    st3 = ServerState(params=w, t=4)
    st4 = server_receive(st3, w_new, tau=0, fed=fed)
    beta = 0.7 * 5 ** -0.5
    np.testing.assert_allclose(np.asarray(st4.params["a"]), beta, rtol=1e-6)


def test_staleness_clamped_at_K():
    fed = FedConfig(mixing_beta=0.7, staleness_a=0.5, max_staleness=4)
    w = {"a": jnp.zeros(1)}
    st = ServerState(params=w, t=100)
    st2 = server_receive(st, {"a": jnp.ones(1)}, tau=0, fed=fed)
    beta = 0.7 * (1 + 4) ** -0.5
    np.testing.assert_allclose(np.asarray(st2.params["a"]), beta, rtol=1e-6)


def test_batched_server_receive_matches_chained():
    """``server_receive_many`` (one fused lax.scan mix) must equal m
    chained ``server_receive`` calls: same per-position staleness/β_t
    (the i-th update of a group lands at epoch t+i) and the same mixed
    params — Algorithm 1's sequential order, one dispatch."""
    fed = FedConfig(mixing_beta=0.7, staleness_a=0.5, max_staleness=4)
    rng = np.random.default_rng(3)

    def tree():
        return {"a": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32),
                "b": jnp.asarray(rng.standard_normal(5), jnp.float32)}

    updates = [(tree(), tau) for tau in (2, 0, 3, 1)]
    st0 = ServerState(params=tree(), t=3)

    chained = st0
    for w_new, tau in updates:
        chained = server_receive(chained, w_new, tau, fed)

    fused, stals, betas = fedasync.server_receive_many(st0, updates, fed)
    assert fused.t == chained.t == st0.t + len(updates)
    assert fused.total_updates == chained.total_updates
    # per-position weights: staleness of update i is clamp(t+i-τ_i, 0, K)
    want = [min(max(st0.t + i - tau, 0), fed.max_staleness)
            for i, (_, tau) in enumerate(updates)]
    assert stals == want
    np.testing.assert_allclose(
        betas, [0.7 * (1 + s) ** -0.5 for s in want], rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(fused.params),
                    jax.tree_util.tree_leaves(chained.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

    # singleton groups take the scalar _mix path and still agree
    one, stals1, _ = fedasync.server_receive_many(st0, updates[:1], fed)
    w0, tau0 = updates[0]
    ref = server_receive(st0, w0, tau0, fed)
    assert stals1 == want[:1]
    for a, b in zip(jax.tree_util.tree_leaves(one.params),
                    jax.tree_util.tree_leaves(ref.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_proximal_gradient():
    from repro.optim.proximal import proximal_grad, proximal_penalty
    g = {"w": jnp.ones(3)}
    p = {"w": jnp.full(3, 2.0)}
    anchor = {"w": jnp.zeros(3)}
    out = proximal_grad(g, p, anchor, theta=0.5)
    np.testing.assert_allclose(np.asarray(out["w"]), 1.0 + 0.5 * 2.0)
    pen = proximal_penalty(p, anchor, 0.5)
    np.testing.assert_allclose(float(pen), 0.5 * 0.5 * 12.0)
    assert proximal_grad(g, p, anchor, 0.0) is g


def test_fedavg_weighted_average():
    trees = [{"w": jnp.zeros(2)}, {"w": jnp.ones(2)}, {"w": jnp.full(2, 4.0)}]
    w = jnp.asarray([0.5, 0.25, 0.25])
    avg = fedavg.weighted_average(trees, w)
    np.testing.assert_allclose(np.asarray(avg["w"]), 0.25 + 1.0)


def test_client_update_quadratic_converges():
    """On a quadratic task the proximal client step solves the paper's local
    objective: min l(w) + θ/2||w - w_t||² has closed form; check descent."""
    from repro.models import registry  # noqa: F401 (import check)
    # emulate with direct optimizer machinery on a toy loss
    from repro.optim import sgd
    from repro.optim.proximal import proximal_grad
    target = jnp.asarray([3.0, -2.0])
    w0 = {"w": jnp.zeros(2)}
    theta = 0.3
    opt = sgd(0.1)
    state = opt.init(w0)
    w = w0
    for _ in range(200):
        grads = {"w": (w["w"] - target)}
        grads = proximal_grad(grads, w, w0, theta)
        w, state = opt.update(grads, state, w)
    # fixed point of l + prox: w* = (target + θ·w0)/(1+θ)
    np.testing.assert_allclose(np.asarray(w["w"]),
                               np.asarray(target) / (1 + theta), rtol=1e-3)


# ---------------------------------------------------------------------------
# Convergence bound (Theorem §IV-B)
# ---------------------------------------------------------------------------

def test_bound_decreases_with_E():
    base = dict(beta=0.7, eta=0.01, eps=1.0, K=4, lam=3.0, H_min=1,
                F0_minus_FE=5.0)
    b1 = convergence.bound(convergence.BoundInputs(E=10, **base))
    b2 = convergence.bound(convergence.BoundInputs(E=1000, **base))
    assert b2 < b1


def test_asymptotic_term_matches_paper():
    b = convergence.BoundInputs(E=10**9, beta=0.7, eta=1e-9, eps=2.0, K=4,
                                lam=3.0, H_min=1, F0_minus_FE=5.0)
    asym = convergence.asymptotic_bound(b)
    np.testing.assert_allclose(asym, 0.7 * 4 * 3.0 / 2.0)
    # with eta = 1/sqrt(E) and E large, total bound approaches the
    # staleness term + optimality term; eps scaling kills it
    big = convergence.BoundInputs(E=10**8,
                                  eta=convergence.lr_schedule_for_asymptotic(
                                      10**8),
                                  beta=0.7, eps=100.0, K=4, lam=3.0, H_min=1,
                                  F0_minus_FE=5.0)
    assert convergence.bound(big) < 1.0


def test_bound_monotonicities():
    base = dict(E=100, beta=0.7, eta=0.01, eps=1.0, H_min=1, F0_minus_FE=5.0)
    t_k2 = convergence.bound_terms(
        convergence.BoundInputs(K=2, lam=3.0, **base))
    t_k8 = convergence.bound_terms(
        convergence.BoundInputs(K=8, lam=3.0, **base))
    assert t_k8["staleness"] > t_k2["staleness"]
    t_l1 = convergence.bound_terms(
        convergence.BoundInputs(K=4, lam=1.0, **base))
    t_l5 = convergence.bound_terms(
        convergence.BoundInputs(K=4, lam=5.0, **base))
    assert t_l5["local_drift"] > t_l1["local_drift"]


def test_theta_condition():
    assert not convergence.theta_condition(0.1, mu=0.5, eps=1.0, B2=1.0,
                                           drift_sq=1.0)   # θ <= μ
    th = convergence.min_theta(mu=0.5, eps=1.0, B2=1.0, drift_sq=4.0)
    assert np.isfinite(th)
    assert convergence.theta_condition(th + 1e-6, 0.5, 1.0, 1.0, 4.0)
    assert not convergence.theta_condition(th - 0.1, 0.5, 1.0, 1.0, 4.0)


# ---------------------------------------------------------------------------
# Communication-efficient updates (int8 delta quantization)
# ---------------------------------------------------------------------------

def test_quantized_delta_roundtrip_error_bound():
    from repro.core.compression import (compression_ratio, quantize_delta,
                                        roundtrip)
    rng = np.random.default_rng(0)
    anchor = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32),
              "b": jnp.asarray(rng.standard_normal(64), jnp.float32)}
    w_new = jax.tree_util.tree_map(
        lambda a: a + 0.01 * jnp.asarray(
            rng.standard_normal(a.shape), jnp.float32), anchor)
    recon, upd = roundtrip(w_new, anchor)
    # max error <= scale/2 per leaf
    for r, w, s in zip(jax.tree_util.tree_leaves(recon),
                       jax.tree_util.tree_leaves(w_new),
                       jax.tree_util.tree_leaves(upd.scale)):
        assert float(jnp.max(jnp.abs(r - w))) <= float(s) * 0.51
    assert compression_ratio(upd) > 3.5      # ~4x vs f32


def test_async_fl_with_compression_converges():
    from repro.configs import RESNET18
    from repro.core import simulator
    from repro.core.simulator import JETSON_FLEET_HMDB51
    from repro.data import BatchLoader, SyntheticActionDataset, iid_partition
    from repro.models import registry
    cfg = RESNET18.reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticActionDataset(num_classes=8, samples_per_class=8, seed=1)
    parts = iid_partition(len(ds), 4)
    data = [BatchLoader(ds, 4, steps=4, seed=k, indices=parts[k])
            for k in range(4)]
    losses = {}
    for bits in (0, 8):
        fed = FedConfig(num_clients=4, global_epochs=10, local_iters_min=1,
                        local_iters_max=2, lr=0.05, compress_bits=bits)
        res = simulator.run_async(params, cfg, fed, JETSON_FLEET_HMDB51,
                                  data)
        losses[bits] = res.final_loss
    # compression costs little accuracy at smoke scale
    assert np.isfinite(losses[8])
    assert losses[8] < losses[0] * 2.0 + 2.0
