"""Heterogeneous-fleet simulator: the paper's wall-clock claims."""
import numpy as np
import pytest

import jax

from repro.configs import RESNET18
from repro.core import simulator
from repro.core.simulator import (JETSON_FLEET_HMDB51, JETSON_FLEET_UCF101,
                                  analytic_speedup)
from repro.data import BatchLoader, SyntheticActionDataset, iid_partition
from repro.models import registry
from repro.types import FedConfig


def test_fleet_profiles_match_paper_table4():
    t = {p.name: p.epoch_seconds for p in JETSON_FLEET_HMDB51}
    assert t["jetson-nano"] == 391.1
    assert t["jetson-agx-xavier"] == 84.5
    # 4.7x spread the paper cites
    assert 4.5 < t["jetson-nano"] / t["jetson-agx-xavier"] < 4.8
    u = {p.name: p.epoch_seconds for p in JETSON_FLEET_UCF101}
    assert u["jetson-nano"] == 2691.6


def test_analytic_async_beats_sync_both_datasets():
    for fleet in (JETSON_FLEET_HMDB51, JETSON_FLEET_UCF101):
        sp = analytic_speedup(fleet, epochs=80, local_epochs=3)
        assert sp["async_s"] < sp["sync_s"]
        assert sp["reduction"] > 0.3     # the paper reports ~40%


def test_table2_wall_clock_reduction_at_least_35pct():
    """The paper's Table II headline: async cuts wall-clock ≈40% vs sync.

    The analytic model (docs/simulator.md, "The Table II claim"):
      sync  = (E / n) · max_k T_k          — every round waits for the
                                              slowest device
      async = E / Σ_k (1 / T_k)            — clients stream independently
                                              at aggregate rate Σ 1/T_k
    with T_k = epoch_seconds_k · local_epochs + upload_seconds_k. Both
    Jetson fleets (Tables IV/V) must show ≥35% reduction at the paper's
    operating point (E=80, 3 local epochs).
    """
    for fleet in (JETSON_FLEET_HMDB51, JETSON_FLEET_UCF101):
        sp = analytic_speedup(fleet, epochs=80, local_epochs=3)
        assert sp["reduction"] >= 0.35, (fleet[0], sp)


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = RESNET18.reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticActionDataset(num_classes=8, samples_per_class=8, seed=1)
    fed = FedConfig(num_clients=4, global_epochs=12, local_iters_min=1,
                    local_iters_max=2, lr=0.05, trainable="all")
    parts = iid_partition(len(ds), 4)
    data = [BatchLoader(ds, 4, steps=4, seed=k, indices=parts[k])
            for k in range(4)]
    return cfg, params, ds, fed, data


@pytest.mark.slow
def test_async_run(tiny_setup):
    cfg, params, ds, fed, data = tiny_setup
    res = simulator.run_async(params, cfg, fed, JETSON_FLEET_HMDB51, data)
    assert res.wall_clock_s > 0
    assert len(res.history) == fed.global_epochs
    assert sum(res.staleness_hist.values()) == fed.global_epochs
    # some staleness observed on a heterogeneous fleet
    assert max(res.staleness_hist) >= 1
    assert np.isfinite(res.final_loss)


@pytest.mark.slow
def test_async_wallclock_beats_sync(tiny_setup):
    cfg, params, ds, fed, data = tiny_setup
    ra = simulator.run_async(params, cfg, fed, JETSON_FLEET_HMDB51, data)
    rs = simulator.run_sync(params, cfg, fed, JETSON_FLEET_HMDB51, data)
    assert ra.wall_clock_s < rs.wall_clock_s
    # losses decrease in both
    assert ra.history[-1][2] < ra.history[0][2] * 2
    assert rs.history[-1][2] < rs.history[0][2] * 2


# ---------------------------------------------------------------------------
# Staleness-bounded async micro-batching window
# ---------------------------------------------------------------------------

def _fresh_data(ds, parts, n=4):
    """Fresh BatchLoaders: the loader is stateful across calls (each call
    is a new local epoch), so parity runs each need their own set."""
    return [BatchLoader(ds, 4, steps=4, seed=k, indices=parts[k])
            for k in range(n)]


@pytest.mark.slow
def test_window_zero_matches_event_by_event(tiny_setup):
    """window=0 IS the legacy loop: singleton groups, the scalar ``_mix``
    path, re-dispatch immediately after each receive — bit-identical
    params across repeated runs, and trace/staleness parity with the
    per-iteration loop oracle."""
    cfg, params, ds, fed, _ = tiny_setup
    parts = iid_partition(len(ds), 4)
    res = simulator.run_async(params, cfg, fed, JETSON_FLEET_HMDB51,
                              _fresh_data(ds, parts), window=0.0)
    again = simulator.run_async(params, cfg, fed, JETSON_FLEET_HMDB51,
                                _fresh_data(ds, parts), window=0.0)
    key = [(e.kind, e.client, e.global_epoch, e.staleness) for e in res.trace]
    assert key == [(e.kind, e.client, e.global_epoch, e.staleness)
                   for e in again.trace]
    for a, b in zip(jax.tree_util.tree_leaves(res.params),
                    jax.tree_util.tree_leaves(again.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # event-by-event invariants of the legacy loop
    assert res.group_hist == {1: fed.global_epochs}
    assert sum(res.staleness_hist.values()) == fed.global_epochs
    # every receive (while budget remains) is immediately followed by that
    # client's re-dispatch — no deferred bursts at window=0
    recv = [(i, e) for i, e in enumerate(res.trace) if e.kind == "receive"]
    for i, e in recv:
        if e.global_epoch < fed.global_epochs:
            nxt = res.trace[i + 1]
            assert (nxt.kind, nxt.client) == ("dispatch", e.client)
    # the loop oracle sees the same event order and staleness accounting
    oracle = simulator.run_async(params, cfg, fed, JETSON_FLEET_HMDB51,
                                 _fresh_data(ds, parts), engine="loop",
                                 window=0.0)
    assert key == [(e.kind, e.client, e.global_epoch, e.staleness)
                   for e in oracle.trace]
    assert res.staleness_hist == oracle.staleness_hist
    for a, b in zip(jax.tree_util.tree_leaves(res.params),
                    jax.tree_util.tree_leaves(oracle.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)


@pytest.mark.slow
def test_positive_window_groups_and_respects_staleness_bound(tiny_setup):
    """A positive window forms multi-receive groups on a heterogeneous
    fleet but never admits a receive whose position-in-group staleness
    would exceed fed.max_staleness (Assumption 3)."""
    cfg, params, ds, fed, _ = tiny_setup
    parts = iid_partition(len(ds), 4)
    res = simulator.run_async(params, cfg, fed, JETSON_FLEET_HMDB51,
                              _fresh_data(ds, parts), window=300.0)
    assert sum(k * v for k, v in res.group_hist.items()) == fed.global_epochs
    assert max(res.group_hist) > 1              # grouping actually happened
    assert sum(res.staleness_hist.values()) == fed.global_epochs
    assert len(res.history) == fed.global_epochs
    assert np.isfinite(res.final_loss)
    # tight K: an unbounded window must cap its groups at the staleness
    # bound, and every traced receive stays within it
    import dataclasses
    fed_k = dataclasses.replace(fed, max_staleness=2)
    res_k = simulator.run_async(params, cfg, fed_k, JETSON_FLEET_HMDB51,
                                _fresh_data(ds, parts), window=1e9)
    recv = [e for e in res_k.trace if e.kind == "receive"]
    assert recv and all(e.staleness <= fed_k.max_staleness for e in recv)
    # with K=2 a group's 4th member would sit at staleness 3: impossible
    assert max(res_k.group_hist) <= fed_k.max_staleness + 1


def test_client_time_jitter_is_mean_preserving():
    """lognormal(mean=-σ²/2, σ) has a mean-one multiplier: jitter must add
    variance, not silently inflate every simulated wall-clock by
    exp(σ²/2)."""
    from repro.core.simulator import DeviceProfile, _client_time
    profile = DeviceProfile("d", 100.0, upload_seconds=5.0)
    rng = np.random.default_rng(0)
    base = _client_time(profile, 3, 1, rng, jitter=0.0)
    sigma = 0.5
    draws = np.array([_client_time(profile, 3, 1, rng, jitter=sigma)
                      for _ in range(20000)])
    np.testing.assert_allclose(draws.mean(), base, rtol=0.02)
    # and it really is jitter, not a constant
    assert draws.std() > 0.1 * base


def test_homogeneous_fleet_no_staleness_advantage():
    """With identical devices sync and async rates coincide (sanity)."""
    from repro.core.simulator import DeviceProfile
    fleet = tuple(DeviceProfile(f"d{i}", 100.0) for i in range(4))
    sp = analytic_speedup(fleet, epochs=80, local_epochs=3)
    np.testing.assert_allclose(sp["sync_s"], sp["async_s"], rtol=1e-9)
