"""Heterogeneous-fleet simulator: the paper's wall-clock claims."""
import numpy as np
import pytest

import jax

from repro.configs import RESNET18
from repro.core import simulator
from repro.core.simulator import (JETSON_FLEET_HMDB51, JETSON_FLEET_UCF101,
                                  analytic_speedup)
from repro.data import BatchLoader, SyntheticActionDataset, iid_partition
from repro.models import registry
from repro.types import FedConfig


def test_fleet_profiles_match_paper_table4():
    t = {p.name: p.epoch_seconds for p in JETSON_FLEET_HMDB51}
    assert t["jetson-nano"] == 391.1
    assert t["jetson-agx-xavier"] == 84.5
    # 4.7x spread the paper cites
    assert 4.5 < t["jetson-nano"] / t["jetson-agx-xavier"] < 4.8
    u = {p.name: p.epoch_seconds for p in JETSON_FLEET_UCF101}
    assert u["jetson-nano"] == 2691.6


def test_analytic_async_beats_sync_both_datasets():
    for fleet in (JETSON_FLEET_HMDB51, JETSON_FLEET_UCF101):
        sp = analytic_speedup(fleet, epochs=80, local_epochs=3)
        assert sp["async_s"] < sp["sync_s"]
        assert sp["reduction"] > 0.3     # the paper reports ~40%


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = RESNET18.reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticActionDataset(num_classes=8, samples_per_class=8, seed=1)
    fed = FedConfig(num_clients=4, global_epochs=12, local_iters_min=1,
                    local_iters_max=2, lr=0.05, trainable="all")
    parts = iid_partition(len(ds), 4)
    data = [BatchLoader(ds, 4, steps=4, seed=k, indices=parts[k])
            for k in range(4)]
    return cfg, params, ds, fed, data


@pytest.mark.slow
def test_async_run(tiny_setup):
    cfg, params, ds, fed, data = tiny_setup
    res = simulator.run_async(params, cfg, fed, JETSON_FLEET_HMDB51, data)
    assert res.wall_clock_s > 0
    assert len(res.history) == fed.global_epochs
    assert sum(res.staleness_hist.values()) == fed.global_epochs
    # some staleness observed on a heterogeneous fleet
    assert max(res.staleness_hist) >= 1
    assert np.isfinite(res.final_loss)


@pytest.mark.slow
def test_async_wallclock_beats_sync(tiny_setup):
    cfg, params, ds, fed, data = tiny_setup
    ra = simulator.run_async(params, cfg, fed, JETSON_FLEET_HMDB51, data)
    rs = simulator.run_sync(params, cfg, fed, JETSON_FLEET_HMDB51, data)
    assert ra.wall_clock_s < rs.wall_clock_s
    # losses decrease in both
    assert ra.history[-1][2] < ra.history[0][2] * 2
    assert rs.history[-1][2] < rs.history[0][2] * 2


def test_client_time_jitter_is_mean_preserving():
    """lognormal(mean=-σ²/2, σ) has a mean-one multiplier: jitter must add
    variance, not silently inflate every simulated wall-clock by
    exp(σ²/2)."""
    from repro.core.simulator import DeviceProfile, _client_time
    profile = DeviceProfile("d", 100.0, upload_seconds=5.0)
    rng = np.random.default_rng(0)
    base = _client_time(profile, 3, 1, rng, jitter=0.0)
    sigma = 0.5
    draws = np.array([_client_time(profile, 3, 1, rng, jitter=sigma)
                      for _ in range(20000)])
    np.testing.assert_allclose(draws.mean(), base, rtol=0.02)
    # and it really is jitter, not a constant
    assert draws.std() > 0.1 * base


def test_homogeneous_fleet_no_staleness_advantage():
    """With identical devices sync and async rates coincide (sanity)."""
    from repro.core.simulator import DeviceProfile
    fleet = tuple(DeviceProfile(f"d{i}", 100.0) for i in range(4))
    sp = analytic_speedup(fleet, epochs=80, local_epochs=3)
    np.testing.assert_allclose(sp["sync_s"], sp["async_s"], rtol=1e-9)
