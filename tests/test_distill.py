"""Knowledge distillation (paper §III-B, §V-A)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import distill
from repro.configs import RESNET18, RESNET26, RESNET34
from repro.data import SyntheticActionDataset, BatchLoader
from repro.models import registry
from repro.types import DistillConfig


def test_kd_loss_formula(rng):
    s = jnp.asarray(rng.standard_normal((8, 40)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((8, 40)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, 40, 8), jnp.int32)
    # alpha=1 -> pure CE ; alpha=0 -> pure MSE-sum
    ce = distill.kd_loss(s, t, lab, alpha=1.0)
    mse = distill.kd_loss(s, t, lab, alpha=0.0)
    want_mse = jnp.mean(jnp.sum((s - t) ** 2, axis=-1))
    np.testing.assert_allclose(float(mse), float(want_mse), rtol=1e-6)
    mid = distill.kd_loss(s, t, lab, alpha=0.3)
    np.testing.assert_allclose(float(mid), 0.3 * float(ce)
                               + 0.7 * float(want_mse), rtol=1e-6)


def test_kd_loss_kernel_path_matches(rng):
    s = jnp.asarray(rng.standard_normal((6, 4, 100)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((6, 4, 100)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, 100, (6, 4)), jnp.int32)
    a = distill.kd_loss(s, t, lab, 0.5, use_kernel=False)
    b = distill.kd_loss(s, t, lab, 0.5, use_kernel=True)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5)


@pytest.mark.slow
def test_distillation_chain_runs_and_reports():
    """teacher -> TA -> student chain executes; accuracies are sane."""
    t_cfg, ta_cfg, s_cfg = (RESNET34.reduced(), RESNET26.reduced(),
                            RESNET18.reduced())
    ds = SyntheticActionDataset(num_classes=8, samples_per_class=16,
                                noise=0.3, seed=3)
    loader = BatchLoader(ds, 8, steps=12, seed=0)
    eval_b = list(ds.batches(8, 4, seed=99))
    dcfg = DistillConfig(alpha=0.5, lr=0.02,
                         chain=(t_cfg.name, ta_cfg.name, s_cfg.name))
    params, stages = distill.run_chain(
        [t_cfg, ta_cfg, s_cfg], dcfg, loader, eval_b,
        steps_per_stage=12, seed=0, trained_teacher_steps=12)
    assert len(stages) == 2
    assert stages[0].teacher == t_cfg.name
    assert stages[1].student == s_cfg.name
    for st in stages:
        assert np.isfinite(st.losses).all()
        assert st.losses[-1] < st.losses[0] * 1.5   # didn't blow up
        assert 0.0 <= st.accuracy <= 1.0


def test_chain_time_model_monotone():
    """Table I shape: more TAs => strictly more time."""
    chains = [
        [RESNET34, RESNET18],
        [RESNET34, RESNET26, RESNET18],
    ]
    times = [distill.chain_time_model(c, dataset_items=1e6, epochs=200)
             ["total_s"] for c in chains]
    assert times[1] > times[0]
    # FLOPs-proportional model: adding the TA stage grows time but less
    # than doubles-per-stage would naively suggest (the paper's measured
    # +23% is smaller still — its wall time is input-pipeline bound).
    ratio = times[1] / times[0]
    assert 1.05 < ratio < 3.0


def test_vocab_mismatch_rejected():
    import dataclasses
    bad = dataclasses.replace(RESNET18, vocab_size=7, num_classes=7,
                              name="resnet3d-18")
    with pytest.raises(ValueError, match="equal logit width"):
        distill.run_chain([RESNET34, bad], DistillConfig(),
                          lambda: iter([]), [], steps_per_stage=0,
                          teacher_params={})
