"""Knowledge distillation (paper §III-B, §V-A)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import distill
from repro.configs import RESNET18, RESNET26, RESNET34
from repro.data import SyntheticActionDataset, BatchLoader
from repro.models import registry
from repro.types import DistillConfig


def test_kd_loss_formula(rng):
    s = jnp.asarray(rng.standard_normal((8, 40)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((8, 40)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, 40, 8), jnp.int32)
    # alpha=1 -> pure CE ; alpha=0 -> pure MSE-sum
    ce = distill.kd_loss(s, t, lab, alpha=1.0)
    mse = distill.kd_loss(s, t, lab, alpha=0.0)
    want_mse = jnp.mean(jnp.sum((s - t) ** 2, axis=-1))
    np.testing.assert_allclose(float(mse), float(want_mse), rtol=1e-6)
    mid = distill.kd_loss(s, t, lab, alpha=0.3)
    np.testing.assert_allclose(float(mid), 0.3 * float(ce)
                               + 0.7 * float(want_mse), rtol=1e-6)


def test_kd_loss_kernel_path_matches(rng):
    """kd_kernel='pallas' (the default) == the eager jnp oracle — the
    same flag discipline as serving's decode_kernel."""
    s = jnp.asarray(rng.standard_normal((6, 4, 100)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((6, 4, 100)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, 100, (6, 4)), jnp.int32)
    a = distill.kd_loss(s, t, lab, 0.5, kd_kernel="eager")
    b = distill.kd_loss(s, t, lab, 0.5, kd_kernel="pallas")
    np.testing.assert_allclose(float(a), float(b), rtol=1e-5)
    # temperature routes through both paths identically
    at = distill.kd_loss(s, t, lab, 0.5, temperature=3.0,
                         kd_kernel="eager")
    bt = distill.kd_loss(s, t, lab, 0.5, temperature=3.0,
                         kd_kernel="pallas")
    np.testing.assert_allclose(float(at), float(bt), rtol=1e-5)


def test_kd_kernel_flag_validated(rng):
    s = jnp.zeros((2, 8), jnp.float32)
    lab = jnp.zeros((2,), jnp.int32)
    with pytest.raises(ValueError, match="kd_kernel"):
        distill.kd_loss(s, s, lab, 0.5, kd_kernel="einsum")
    with pytest.raises(ValueError, match="kd_kernel"):
        distill.DistillEngine(RESNET18.reduced(), RESNET18.reduced(),
                              DistillConfig(), kd_kernel="cuda")


TINY_LM = dict(family="dense", num_layers=1, d_model=32, num_heads=2,
               num_kv_heads=2, d_ff=64, vocab_size=64)


def _tiny_lm(name, **over):
    from repro.types import ModelConfig
    return ModelConfig(name=name, **{**TINY_LM, **over})


def test_distill_engine_epoch_matches_per_step(rng):
    """The scan-compiled epoch program == iterating the single-step
    entry: same final params, same per-step losses."""
    from repro.data import SyntheticLMDataset, stack_batches
    tcfg = _tiny_lm("kd-teacher")
    scfg = _tiny_lm("kd-student", d_model=16, d_ff=32)
    dcfg = DistillConfig(lr=0.01, batch_size=2)
    ds = SyntheticLMDataset(vocab=64, seq_len=8, seed=0)
    batches = list(ds.batches(2, 3, seed=1))
    stacked = stack_batches(iter(batches))

    engine = distill.DistillEngine(tcfg, scfg, dcfg)
    t_params = registry.init_params(jax.random.PRNGKey(0), tcfg)
    params0 = registry.init_params(jax.random.PRNGKey(1), scfg)
    opt0 = engine.opt.init(params0)

    pe, oe, le = engine.epoch(t_params, params0, opt0, stacked)
    ps, os_, ls = params0, opt0, []
    for b in batches:
        b = jax.tree_util.tree_map(jnp.asarray, b)
        ps, os_, loss = engine.step(t_params, ps, os_, b)
        ls.append(float(loss))
    np.testing.assert_allclose(np.asarray(jax.device_get(le)),
                               np.asarray(ls), rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(pe),
                    jax.tree_util.tree_leaves(ps)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_distill_engine_memoized():
    tcfg, scfg = RESNET34.reduced(), RESNET18.reduced()
    dcfg = DistillConfig(lr=0.01)
    e1 = distill.make_distill_engine(tcfg, scfg, dcfg)
    e2 = distill.make_distill_engine(tcfg, scfg, dcfg)
    assert e1 is e2                     # compiled epochs are reused
    e3 = distill.make_distill_engine(tcfg, scfg, dcfg, kd_kernel="eager")
    assert e3 is not e1                 # kernel choice is program identity
    s1 = distill.make_scratch_run(tcfg, dcfg)
    s2 = distill.make_scratch_run(tcfg, dcfg)
    assert s1 is s2


def test_codistill_heterogeneous_fleet_batches_by_arch(rng):
    """Codistillation: members sharing an arch run as ONE vmapped masked-
    scan program; per-member budgets mask via NaN; warm rounds compile
    nothing new."""
    from repro.data import SyntheticLMDataset, stack_batches
    a = _tiny_lm("co-big")
    b = _tiny_lm("co-small", d_model=16, d_ff=32)
    dcfg = DistillConfig(lr=0.01)
    fleet = distill.CodistillFleet([a, a, b], dcfg).init(
        jax.random.PRNGKey(0))
    ds = SyntheticLMDataset(vocab=64, seq_len=8, seed=0)
    probe = stack_batches(iter(ds.batches(2, 4, seed=1)))

    losses = np.asarray(jax.device_get(
        fleet.round(probe, iters=[4, 2, 3])))
    assert losses.shape == (3, 4)
    assert np.isfinite(losses[0]).all()                  # full budget
    assert np.isfinite(losses[1, :2]).all() and np.isnan(losses[1, 2:]).all()
    assert np.isfinite(losses[2, :3]).all() and np.isnan(losses[2, 3:]).all()
    # 2 architecture groups x (logits + kd) programs — NOT 3 members x 2
    assert fleet.num_compiled == 4

    n0 = fleet.num_compiled
    probe2 = stack_batches(iter(ds.batches(2, 4, seed=2)))
    fleet.round(probe2)                                  # warm, full iters
    assert fleet.num_compiled == n0

    # member params keep their own arch shapes
    t1 = jax.tree_util.tree_structure(fleet.member_params(0))
    t2 = jax.tree_util.tree_structure(
        registry.init_params(jax.random.PRNGKey(9), a))
    assert t1 == t2


def test_codistill_rejects_bad_fleets():
    a = _tiny_lm("co-a")
    with pytest.raises(ValueError, match=">= 2"):
        distill.CodistillFleet([a], DistillConfig())
    import dataclasses
    other_vocab = dataclasses.replace(a, name="co-v", vocab_size=32)
    with pytest.raises(ValueError, match="equal logit width"):
        distill.CodistillFleet([a, other_vocab], DistillConfig())
    same_width_resnet = dataclasses.replace(RESNET18.reduced(),
                                            num_classes=a.vocab_size)
    with pytest.raises(ValueError, match="probe batch"):
        distill.CodistillFleet([a, same_width_resnet], DistillConfig())


@pytest.mark.slow
def test_distillation_chain_runs_and_reports():
    """teacher -> TA -> student chain executes; accuracies are sane."""
    t_cfg, ta_cfg, s_cfg = (RESNET34.reduced(), RESNET26.reduced(),
                            RESNET18.reduced())
    ds = SyntheticActionDataset(num_classes=8, samples_per_class=16,
                                noise=0.3, seed=3)
    loader = BatchLoader(ds, 8, steps=12, seed=0)
    eval_b = list(ds.batches(8, 4, seed=99))
    dcfg = DistillConfig(alpha=0.5, lr=0.02,
                         chain=(t_cfg.name, ta_cfg.name, s_cfg.name))
    params, stages = distill.run_chain(
        [t_cfg, ta_cfg, s_cfg], dcfg, loader, eval_b,
        steps_per_stage=12, seed=0, trained_teacher_steps=12)
    assert len(stages) == 2
    assert stages[0].teacher == t_cfg.name
    assert stages[1].student == s_cfg.name
    for st in stages:
        assert np.isfinite(st.losses).all()
        assert st.losses[-1] < st.losses[0] * 1.5   # didn't blow up
        assert 0.0 <= st.accuracy <= 1.0


def test_chain_time_model_monotone():
    """Table I shape: more TAs => strictly more time."""
    chains = [
        [RESNET34, RESNET18],
        [RESNET34, RESNET26, RESNET18],
    ]
    times = [distill.chain_time_model(c, dataset_items=1e6, epochs=200)
             ["total_s"] for c in chains]
    assert times[1] > times[0]
    # FLOPs-proportional model: adding the TA stage grows time but less
    # than doubles-per-stage would naively suggest (the paper's measured
    # +23% is smaller still — its wall time is input-pipeline bound).
    ratio = times[1] / times[0]
    assert 1.05 < ratio < 3.0


def test_vocab_mismatch_rejected():
    import dataclasses
    bad = dataclasses.replace(RESNET18, vocab_size=7, num_classes=7,
                              name="resnet3d-18")
    with pytest.raises(ValueError, match="equal logit width"):
        distill.run_chain([RESNET34, bad], DistillConfig(),
                          lambda: iter([]), [], steps_per_stage=0,
                          teacher_params={})
