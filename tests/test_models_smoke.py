"""Per-architecture smoke tests: reduced variant, one forward/train step on
CPU, output shapes + no NaNs (assignment requirement), plus decode parity."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.fedasync import make_client_step
from repro.models import registry
from repro.optim import trainable_mask
from repro.types import FedConfig, ShapeConfig

SMOKE = ShapeConfig("smoke", seq_len=64, global_batch=2, kind="train")


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    batch = registry.synth_batch(rng, cfg, SMOKE)
    loss, metrics = registry.loss_fn(params, cfg, batch, remat=False,
                                     q_chunk=32, loss_chunk=32)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss))
    assert 2.0 < float(metrics["ce"]) < 12.0   # ~ln(vocab) at init

    # one full FL client step (grads + proximal + SGD update)
    fed = FedConfig(lr=1e-2, prox_theta=0.1)
    step, opt = make_client_step(cfg, fed,
                                 loss_kwargs=dict(remat=False, q_chunk=32,
                                                  loss_chunk=32))
    mask = trainable_mask(params, "all")
    p2, _, l2 = step(params, opt.init(params), params, batch, mask)
    assert not any(bool(jnp.isnan(x).any())
                   for x in jax.tree_util.tree_leaves(p2))
    # params actually moved
    diff = jax.tree_util.tree_reduce(
        lambda acc, ab: acc + float(jnp.abs(ab).sum()),
        jax.tree_util.tree_map(lambda a, b: a - b, params, p2), 0.0)
    assert diff > 0


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS
                                  if get_config(a).family != "resnet3d"])
def test_decode_step_shapes(arch):
    cfg = get_config(arch).reduced()
    params = registry.init_params(jax.random.PRNGKey(1), cfg)
    cache = registry.init_cache(cfg, 2, 32, jnp.float32)
    if cfg.is_encdec:
        src = jnp.ones((2, 32, cfg.d_model))
        cache = registry.prefill(params, cfg, {"src_embeds": src}, cache,
                                 q_chunk=32)
    tok = jnp.array([1, 2], jnp.int32)
    logits, cache2 = registry.decode_step(params, cfg, tok, cache,
                                          jnp.int32(0))
    assert logits.shape == (2, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch", ["gemma3-12b", "h2o-danube-3-4b",
                                  "mamba2-130m", "hymba-1.5b",
                                  "internlm2-20b", "paligemma-3b"])
def test_prefill_decode_matches_forward(arch, rng):
    """Teacher-forced logits == prefill+decode logits at the same position."""
    cfg = get_config(arch).reduced()
    params = registry.init_params(jax.random.PRNGKey(2), cfg)
    S = 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, S)), jnp.int32)
    prefix = None
    batch = {"tokens": toks}
    if cfg.prefix_len:
        prefix = jnp.asarray(
            rng.standard_normal((2, cfg.prefix_len, cfg.d_model)),
            jnp.float32)
        batch["prefix_embeds"] = prefix

    full = registry.logits_fn(params, cfg, batch, remat=False)
    # prefill first S-1 tokens, decode the S-th
    cache = registry.init_cache(cfg, 2, S + cfg.prefix_len + 4, jnp.float32)
    pre_batch = {"tokens": toks[:, :S - 1]}
    if prefix is not None:
        pre_batch["prefix_embeds"] = prefix
    logits_pre, cache = registry.prefill(params, cfg, pre_batch, cache,
                                         q_chunk=32)
    # prefill's last-position logits == forward at position S-2 (+prefix)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(full[:, -2, :]),
                               rtol=2e-2, atol=2e-3)
    pos = S - 1 + cfg.prefix_len
    logits_dec, _ = registry.decode_step(params, cfg, toks[:, S - 1],
                                         cache, jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(full[:, -1, :]),
                               rtol=2e-2, atol=2e-3)


def test_resnet3d_smoke(rng):
    from repro.configs import RESNET18
    cfg = RESNET18.reduced()
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    batch = registry.synth_batch(rng, cfg, SMOKE)
    loss, _ = registry.loss_fn(params, cfg, batch)
    assert not bool(jnp.isnan(loss))
    logits = registry.logits_fn(params, cfg, batch)
    assert logits.shape == (2, cfg.num_classes)


@pytest.mark.parametrize("arch", ["gemma3-12b", "h2o-danube-3-4b",
                                  "hymba-1.5b"])
def test_ring_cache_decode_parity(arch, rng):
    """Ring-buffer SWA decode == uniform-cache decode (beyond-paper opt)."""
    from repro.models import lm
    cfg = get_config(arch).reduced()
    params = registry.init_params(jax.random.PRNGKey(2), cfg)
    S = 49
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, S)), jnp.int32)
    cache = registry.init_cache(cfg, 2, S + 3, jnp.float32)
    _, cache = registry.prefill(params, cfg, {"tokens": toks[:, :S - 1]},
                                cache, q_chunk=16)
    l1, _ = lm.decode_step(params, cfg, toks[:, S - 1], cache,
                           jnp.int32(S - 1))
    ring = lm.to_ring_cache(cfg, cache, jnp.int32(S - 1))
    l2, ring2 = lm.decode_step_ring(params, cfg, toks[:, S - 1], ring,
                                    jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-3, atol=2e-4)
    # ring cache is strictly smaller for SWA-dominant archs
    full_bytes = sum(x.size for x in jax.tree_util.tree_leaves(cache))
    ring_bytes = sum(x.size for x in jax.tree_util.tree_leaves(ring2))
    if len(lm.swa_layer_ids(cfg)) > 0 and cfg.sliding_window < S:
        assert ring_bytes < full_bytes


@pytest.mark.parametrize("arch", ["gemma3-12b", "hymba-1.5b"])
def test_unrolled_decode_parity(arch, rng):
    from repro.models import lm
    cfg = get_config(arch).reduced()
    params = registry.init_params(jax.random.PRNGKey(3), cfg)
    S = 33
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, S)), jnp.int32)
    cache = registry.init_cache(cfg, 2, S + 3, jnp.float32)
    _, cache = registry.prefill(params, cfg, {"tokens": toks[:, :S - 1]},
                                cache, q_chunk=16)
    l1, _ = lm.decode_step(params, cfg, toks[:, S - 1], cache,
                           jnp.int32(S - 1))
    l2, _ = lm.decode_step(params, cfg, toks[:, S - 1], cache,
                           jnp.int32(S - 1), unroll=True, window_slice=True)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-3, atol=2e-4)
