import os
import sys

# tests run on the host's single real device (dry-run sets its own flags in
# a subprocess; never globally here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def guard_rails():
    """Opt-in runtime guard rails as a context-manager factory.

    Inside ``with guard_rails():`` every *implicit* host->device transfer
    (numpy/python leaves silently hitting a jitted boundary) raises, and
    ``jax.checking_leaks`` catches tracer leaks. Explicit transfers —
    ``jax.device_put``, ``jax.device_get``, ``jnp.asarray`` — stay legal,
    so tests wrap their steady-state region only, after device_put-ing
    their inputs; warm-up/setup stays outside the ``with``.
    """
    import contextlib

    import jax

    @contextlib.contextmanager
    def rails():
        with jax.transfer_guard("disallow"), jax.checking_leaks():
            yield

    return rails


@pytest.fixture
def compile_budget():
    """Context-manager factory pinning a ``JitCache`` compile delta.

    ``with compile_budget(cache, n):`` asserts that at most ``n`` new
    programs were compiled inside the block — the executable form of the
    PR-2 "one program per round shape" and PR-5 "<= bucket ladder"
    claims. ``exact=True`` pins the delta exactly.
    """
    import contextlib

    @contextlib.contextmanager
    def budget(cache, n, exact=False):
        before = cache.num_compiled
        yield
        delta = cache.num_compiled - before
        if exact:
            if delta != n:
                raise AssertionError(
                    f"compile budget: expected exactly {n} new "
                    f"programs, got {delta}")
        elif delta > n:
            raise AssertionError(
                f"compile budget exceeded: {delta} new programs "
                f"(budget {n})")

    return budget


@pytest.fixture(scope="session")
def smoke_shape():
    from repro.types import ShapeConfig
    return ShapeConfig("smoke", seq_len=64, global_batch=2, kind="train")
