import os
import sys

# tests run on the host's single real device (dry-run sets its own flags in
# a subprocess; never globally here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def smoke_shape():
    from repro.types import ShapeConfig
    return ShapeConfig("smoke", seq_len=64, global_batch=2, kind="train")
