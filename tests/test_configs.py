"""The assigned architecture table, verbatim."""
import pytest

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, shape_supported

EXPECT = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
    "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
    "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
    "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
    "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
    "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
    "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    "mamba2-130m": (24, 768, 0, 0, 0, 50280),
    "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_exact_assigned_config(arch):
    cfg = get_config(arch)
    L, d, H, KV, ff, V = EXPECT[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == H
    assert cfg.num_kv_heads == KV
    assert cfg.d_ff == ff
    assert cfg.vocab_size == V
    assert cfg.source  # every config cites its source


def test_moe_structure():
    l4 = get_config("llama4-scout-17b-a16e")
    assert l4.moe.num_experts == 16 and l4.moe.top_k == 1
    gk = get_config("grok-1-314b")
    assert gk.moe.num_experts == 8 and gk.moe.top_k == 2


def test_ssm_structure():
    m2 = get_config("mamba2-130m")
    assert m2.ssm.d_state == 128 and m2.attention_free
    hy = get_config("hymba-1.5b")
    assert hy.ssm.d_state == 16 and hy.family == "hybrid"


def test_gemma3_local_global_pattern():
    g = get_config("gemma3-12b")
    wins = [g.window_for_layer(i) for i in range(12)]
    # 5 local : 1 global
    assert wins[:6] == [1024] * 5 + [0]
    assert wins[6:12] == [1024] * 5 + [0]


def test_hymba_global_layers():
    h = get_config("hymba-1.5b")
    assert h.window_for_layer(0) == 0
    assert h.window_for_layer(15) == 0
    assert h.window_for_layer(31) == 0
    assert h.window_for_layer(1) == 1024


def test_shapes():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_long_context_skip_list():
    runs = [a for a in ASSIGNED_ARCHS
            if shape_supported(get_config(a), SHAPES["long_500k"])[0]]
    assert sorted(runs) == sorted(
        ["mamba2-130m", "hymba-1.5b", "gemma3-12b", "h2o-danube-3-4b"])


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_variants_are_small(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    assert cfg.family == get_config(arch).family


def test_param_counts_plausible():
    # order-of-magnitude sanity vs the names
    assert 250e9 < get_config("grok-1-314b").param_count() < 400e9
    assert 80e9 < get_config("llama4-scout-17b-a16e").param_count() < 130e9
    act = get_config("llama4-scout-17b-a16e").active_param_count()
    assert 10e9 < act < 25e9          # "17B active"
    assert 9e9 < get_config("gemma3-12b").param_count() < 16e9
    assert 100e6 < get_config("mamba2-130m").param_count() < 200e6
