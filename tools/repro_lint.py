#!/usr/bin/env python
"""CLI for repro-lint (see src/repro/analysis/lint.py and
docs/static_analysis.md).

Usage:
  python tools/repro_lint.py               # human report, all findings
  python tools/repro_lint.py --check      # exit 1 on NON-baselined findings
  python tools/repro_lint.py --json      # machine-readable report
  python tools/repro_lint.py --fix-baseline  # regenerate tools/lint_baseline.json
  python tools/repro_lint.py --paths src/repro/core/serving.py  # narrow scope

The baseline (tools/lint_baseline.json) holds pre-existing findings that are
tracked but not blocking; --check fails only on findings outside it.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis import lint  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if any non-baselined finding exists")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON")
    ap.add_argument("--fix-baseline", action="store_true",
                    help="rewrite the baseline from the current findings "
                         "(sorted, deterministic)")
    ap.add_argument("--baseline", default=str(ROOT / "tools" /
                                              "lint_baseline.json"),
                    help="baseline file path")
    ap.add_argument("--paths", nargs="*", default=None,
                    help="files/dirs to scan (repo-relative; default "
                         "src/repro)")
    args = ap.parse_args(argv)

    findings = lint.scan_paths(ROOT, args.paths)

    if args.fix_baseline:
        Path(args.baseline).write_text(lint.make_baseline(findings))
        print(f"baseline written: {args.baseline} "
              f"({len(findings)} findings)")
        return 0

    baseline = lint.load_baseline(args.baseline)
    new = lint.mark_baselined(findings, baseline)

    if args.as_json:
        print(json.dumps({"findings": [f.to_json() for f in findings],
                          "new": len(new),
                          "baselined": len(findings) - len(new)},
                         indent=2))
    else:
        for f in findings:
            tag = "baselined" if f.baselined else "NEW"
            print(f"{f.path}:{f.line}: {f.rule} [{tag}] {f.message}")
        print(f"\n{len(findings)} finding(s): {len(new)} new, "
              f"{len(findings) - len(new)} baselined")
        if new and args.check:
            print("FAIL: new findings above must be fixed, suppressed "
                  "with `# repro-lint: disable=<rule>` + justification, "
                  "or (rarely) baselined via --fix-baseline.")

    if args.check and new:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
