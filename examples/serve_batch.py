"""Batched serving example: prefill + decode across three architecture
families (dense SWA, SSM, VLM-prefix) on this host.

    PYTHONPATH=src python examples/serve_batch.py
"""
import subprocess
import sys

for arch in ("h2o-danube-3-4b", "mamba2-130m", "paligemma-3b"):
    print(f"\n=== {arch} ===")
    subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
         "--reduced", "--batch", "2", "--prompt-len", "16", "--gen", "8"],
        check=True)
