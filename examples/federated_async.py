"""Asynchronous FL on a *transformer* (mamba2-130m reduced) — shows the
paper's Algorithm 1 is model-agnostic across the assigned architectures,
and reproduces the staleness-hyperparameter story (Figs. 9-10): a = 0.5
beats a = 0 (no penalty) and a = 0.9 (over-penalized).

Local training runs on the compiled scan engine (core/fed_engine.py): each
client's H proximal-SGD iterations are one ``lax.scan`` program instead of
H jitted dispatches + H host syncs. Pass ``engine="loop"`` to run the
legacy per-iteration oracle — the last section times both.

    PYTHONPATH=src python examples/federated_async.py
"""
import time

import numpy as np

import jax

from repro.configs import get_config
from repro.core import simulator
from repro.core.fleet import Fleet, JETSON_FLEET_HMDB51
from repro.data import BatchLoader, SyntheticLMDataset
from repro.models import registry
from repro.types import FedConfig


def make_fleet():
    # one Fleet object replaces the old parallel fleet/client_data args;
    # Fleet.from_spec streams 10^6-client populations (docs/fleet.md)
    return Fleet.from_lists(
        JETSON_FLEET_HMDB51,
        [BatchLoader(ds, 4, steps=4, seed=k) for k in range(4)])

cfg = get_config("mamba2-130m").reduced()
params = registry.init_params(jax.random.PRNGKey(0), cfg)
ds = SyntheticLMDataset(vocab=cfg.vocab_size, seq_len=32, seed=0)

print(f"arch: {cfg.name} ({cfg.family}); fleet: "
      f"{[p.name for p in JETSON_FLEET_HMDB51]}")


def make_fed(a):
    return FedConfig(num_clients=4, global_epochs=16, local_iters_min=1,
                     local_iters_max=3, lr=0.05, mixing_beta=0.7,
                     staleness_a=a)


for a in (0.0, 0.5, 0.9):
    fed = make_fed(a)
    res = simulator.run_async(params, cfg, fed, make_fleet())
    tail = float(np.mean([l for _, _, l in res.history[-6:]]))
    print(f"  a={a:3.1f}: tail loss {tail:.4f}  "
          f"wall {res.wall_clock_s/3600:.2f}h  "
          f"staleness {dict(sorted(res.staleness_hist.items()))}")

print("\npaper: a=0.5 converges fastest and reaches the best accuracy; "
      "a=0 ignores staleness, a=0.9 over-damps fast clients.")

# engine comparison: identical virtual clock + numerics (float32 tol),
# different host-side cost. Both paths are warmed first (the sweep above
# only compiled the scan engine) so the timing is steady-state dispatch,
# not XLA compilation.
fed = make_fed(0.5)
walls = {}
for eng in ("scan", "loop"):
    simulator.run_async(params, cfg, make_fed(0.5), make_fleet(),
                        engine=eng)
    t0 = time.perf_counter()
    simulator.run_async(params, cfg, fed, make_fleet(), engine=eng)
    walls[eng] = time.perf_counter() - t0
print(f"\nhost wall-clock, E=16: scan engine {walls['scan']:.2f}s vs "
      f"legacy loop {walls['loop']:.2f}s "
      f"({walls['loop']/walls['scan']:.2f}x)")

# algorithm comparison (core/algorithms.py, docs/algorithms.md): the same
# async run with the algorithm swapped behind the engines. SCAFFOLD
# carries control variates against client drift (its variate delta rides
# the staleness-damped mix); the low-rank/masked-submodel algorithm ships
# capacity-scaled compressed updates — its wire bytes shrink with device
# capacity while the engine still compiles ONE round program.
from repro.core.algorithms import LowRankSubmodel, make_algorithm

print("\nalgorithms, a=0.5:")
for name in ("fedprox", "scaffold", "lowrank"):
    alg = make_algorithm(name)
    res = simulator.run_async(params, cfg, make_fed(0.5), make_fleet(),
                              algorithm=alg)
    tail = float(np.mean([l for _, _, l in res.history[-6:]]))
    extra = ""
    if isinstance(alg, LowRankSubmodel):
        extra = (f"  client capacities "
                 f"{[round(alg.capacity_for(k), 3) for k in range(4)]}")
    print(f"  {name:8s}: tail loss {tail:.4f}{extra}")
