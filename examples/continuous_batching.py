"""Continuous-batching server demo: requests of different lengths stream
through a fixed pool of decode slots; outputs are bit-identical to running
each request alone.

    PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import numpy as np

import jax

from repro.configs import get_config
from repro.core.serving import ContinuousBatcher, generate_single
from repro.models import registry

cfg = get_config("h2o-danube-3-4b").reduced()
params = registry.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)

srv = ContinuousBatcher(params, cfg, max_slots=3, max_len=64)
lengths = [4, 11, 6, 9, 5, 8]
prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
           for n in lengths]
for p in prompts:
    srv.submit(p, max_new=8)

t0 = time.perf_counter()
done = srv.run()
dt = time.perf_counter() - t0
print(f"served {len(done)} requests through 3 slots in {dt:.2f}s "
      f"({sum(len(r.out) for r in done)} tokens)")
print(f"prefill buckets {srv.buckets}: {srv.prefill_compiles} prefill "
      f"compiles for {len(set(lengths))} distinct prompt lengths; "
      f"admit groups {srv.group_admits}")

mismatches = 0
for req, p in zip(done, prompts):
    ref = generate_single(params, cfg, p, 8, max_len=64)
    ok = req.out == ref
    mismatches += not ok
    print(f"  req {req.rid}: prompt len {len(p):2d} -> {req.out[:6]}... "
          f"{'== single-request' if ok else 'MISMATCH'}")
assert mismatches == 0
