"""Quickstart: the paper's two-stage pipeline in ~60 lines.

Stage 1 — knowledge distillation at the server (teacher ResNet3D-34 ->
TA ResNet3D-26 -> student ResNet3D-18, reduced variants) on the "large"
synthetic dataset.
Stage 2 — asynchronous federated fine-tuning (paper Algorithm 1) of the
student across a heterogeneous 4-device Jetson fleet (simulated clocks,
real gradient updates) on the "small" synthetic dataset.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import RESNET18, RESNET26, RESNET34
from repro.core import distill, simulator
from repro.core.simulator import JETSON_FLEET_HMDB51
from repro.data import BatchLoader, SyntheticActionDataset, iid_partition
from repro.types import DistillConfig, FedConfig

# ---------------- stage 1: server-side distillation -----------------------
teacher, ta, student = (RESNET34.reduced(), RESNET26.reduced(),
                        RESNET18.reduced())
kinetics_like = SyntheticActionDataset(num_classes=8, samples_per_class=32,
                                       noise=0.3, seed=0)
loader = BatchLoader(kinetics_like, batch_size=8, steps=15, seed=0)
eval_batches = list(kinetics_like.batches(8, 4, seed=99))

dcfg = DistillConfig(alpha=0.5, lr=0.02,
                     chain=(teacher.name, ta.name, student.name))
student_params, stages = distill.run_chain(
    [teacher, ta, student], dcfg, loader, eval_batches,
    steps_per_stage=15, seed=0, trained_teacher_steps=15)
for s in stages:
    print(f"KD {s.teacher} -> {s.student}: loss {s.losses[0]:.2f} -> "
          f"{s.losses[-1]:.2f}, eval acc {s.accuracy:.3f}")

# ---------------- stage 2: async federated fine-tuning --------------------
hmdb_like = SyntheticActionDataset(num_classes=8, samples_per_class=8,
                                   noise=0.5, seed=5)
fed = FedConfig(num_clients=4, global_epochs=16, local_iters_min=1,
                local_iters_max=3, lr=0.02, mixing_beta=0.7,
                staleness_a=0.5, prox_theta=0.01)
parts = iid_partition(len(hmdb_like), fed.num_clients)
client_data = [BatchLoader(hmdb_like, 4, steps=4, seed=k, indices=parts[k])
               for k in range(fed.num_clients)]

res = simulator.run_async(student_params, student, fed,
                          JETSON_FLEET_HMDB51, client_data)
print(f"\nasync FL: {fed.global_epochs} global epochs in "
      f"{res.wall_clock_s/3600:.2f} simulated hours "
      f"(final loss {res.final_loss:.3f})")
print(f"staleness histogram: {res.staleness_hist}")

res_sync = simulator.run_sync(student_params, student, fed,
                              JETSON_FLEET_HMDB51, client_data)
red = 1 - res.wall_clock_s / res_sync.wall_clock_s
print(f"sync FL would take {res_sync.wall_clock_s/3600:.2f} h -> "
      f"async reduces wall-clock by {100*red:.0f}% (paper: ~40%)")
