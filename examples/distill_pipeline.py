"""Table-I-style experiment: sweep the number of teaching assistants.

Distills ResNet3D-34 -> ... -> ResNet3D-18 (reduced variants) with 0 and 1
intermediate TAs, reporting accuracy and wall time per chain, plus the
full-scale FLOPs-model prediction for 0-3 TAs (the paper's Table I shape:
accuracy saturates while time grows sharply).

    PYTHONPATH=src python examples/distill_pipeline.py
"""
import dataclasses

from repro.configs import RESNET18, RESNET26, RESNET34
from repro.configs.resnet3d import BLOCKS, KINETICS_CLASSES
from repro.core import distill
from repro.data import BatchLoader, SyntheticActionDataset
from repro.types import DistillConfig, ModelConfig


def mk(name: str) -> ModelConfig:
    depth = 2 + 2 * sum(BLOCKS[name])
    return ModelConfig(name=name, family="resnet3d", num_layers=depth,
                       d_model=64, num_heads=0, num_kv_heads=0, d_ff=0,
                       vocab_size=KINETICS_CLASSES,
                       num_classes=KINETICS_CLASSES, source="paper §V-A")


CHAINS = {
    0: [RESNET34, RESNET18],
    1: [RESNET34, RESNET26, RESNET18],
    2: [RESNET34, mk("resnet3d-28"), mk("resnet3d-24"), RESNET18],
    3: [RESNET34, mk("resnet3d-30"), RESNET26, mk("resnet3d-22"), RESNET18],
}

print("full-scale FLOPs-model predictions (Kinetics, 200 epochs):")
base = None
for n, chain in CHAINS.items():
    t = distill.chain_time_model(chain, dataset_items=306_245, epochs=200)
    base = base or t["total_s"]
    print(f"  {n} TAs: {t['total_s']/3600:7.1f} h "
          f"(+{100*(t['total_s']/base-1):.0f}%)  "
          f"[paper: {['44h58m','55h23m','69h35m','85h47m'][n]}]")

print("\nsmoke-scale measured (synthetic data, reduced models):")
ds = SyntheticActionDataset(num_classes=8, samples_per_class=32, noise=0.35,
                            seed=0)
loader = BatchLoader(ds, 8, steps=20, seed=0)
eval_b = list(ds.batches(8, 6, seed=99))
for n in (0, 1):
    chain = [c.reduced() for c in CHAINS[n]]
    _, stages = distill.run_chain(
        chain, DistillConfig(alpha=0.5, lr=0.02), loader, eval_b,
        steps_per_stage=20, seed=0, trained_teacher_steps=20)
    total = sum(s.wall_time_s for s in stages)
    print(f"  {n} TAs: student acc {stages[-1].accuracy:.3f}, "
          f"chain wall {total:.1f}s")
