# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table2 roofline
"""
import sys

from benchmarks import tables
from benchmarks.roofline_table import roofline_table
from benchmarks.kernel_bench import kernel_bench
from benchmarks.fed_engine_bench import fed_engine_bench

ALL = {
    "fedengine": fed_engine_bench,
    "table1": tables.table1_kd_tas,
    "table2": tables.table2_stage_times,
    "table3": tables.table3_accuracy,
    "table4": tables.table4_device_times,
    "table5": tables.table5_inference,
    "sweeps": tables.hyperparam_sweep,
    "noniid": tables.noniid_extension,
    "kernels": kernel_bench,
    "roofline": roofline_table,
}


def main() -> None:
    which = sys.argv[1:] or list(ALL)
    rows = []
    for name in which:
        rows.extend(ALL[name]() or [])
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == '__main__':
    main()
