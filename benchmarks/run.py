# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table2 roofline
    PYTHONPATH=src python -m benchmarks.run --list     # names only, no run

A bench may return ``(rows, artifact_paths)`` instead of plain rows to
register machine-readable outputs (e.g. ``fedengine`` writes
``BENCH_fed_engine.json`` with loop vs homogeneous-vmap vs
padded-heterogeneous-vmap round steps/sec and the async window sweep);
artifacts are listed after the CSV.
"""
import sys

from benchmarks import tables
from benchmarks.roofline_table import roofline_table
from benchmarks.kernel_bench import kernel_bench
from benchmarks.fed_engine_bench import fed_engine_bench
from benchmarks.fleet_bench import fleet_bench
from benchmarks.serving_bench import serving_bench
from benchmarks.distill_bench import distill_bench

ALL = {
    "fedengine": fed_engine_bench,
    "fleet": fleet_bench,
    "serving": serving_bench,
    "distill": distill_bench,
    "table1": tables.table1_kd_tas,
    "table2": tables.table2_stage_times,
    "table3": tables.table3_accuracy,
    "table4": tables.table4_device_times,
    "table5": tables.table5_inference,
    "sweeps": tables.hyperparam_sweep,
    "noniid": tables.noniid_extension,
    "kernels": kernel_bench,
    "roofline": roofline_table,
}


def main() -> None:
    if "--list" in sys.argv[1:]:
        # import-level smoke (CI): every bench resolved, nothing executed
        for name, fn in ALL.items():
            doc = (fn.__doc__ or "").strip().splitlines()
            print(f"{name}: {doc[0] if doc else ''}")
        return
    which = sys.argv[1:] or list(ALL)
    rows, artifacts = [], []
    for name in which:
        out = ALL[name]() or []
        if isinstance(out, tuple):       # (rows, artifact_paths)
            out, paths = out
            artifacts.extend(paths)
        rows.extend(out)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    for p in artifacts:
        print(f"artifact: {p}")


if __name__ == '__main__':
    main()
