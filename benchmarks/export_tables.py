"""Render the §Dry-run / §Roofline markdown tables from dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.export_tables [tag] > table.md
"""
from __future__ import annotations

import sys

from benchmarks.roofline_table import load_rows


def fmt(tag="baseline", mesh=None):
    rows = load_rows(tag=tag)
    rows = [r for r in rows if mesh is None or r["mesh"] == mesh]
    out = ["| arch | shape | mesh | compute | HBM | collective | dominant | "
           "peak GiB | useful-FLOP | MFU |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']*1e3:.1f} ms | {r['memory_s']*1e3:.1f} ms "
            f"| {r['collective_s']*1e3:.1f} ms | **{r['dominant']}** "
            f"| {r['peak_memory_bytes']/2**30:.2f} "
            f"| {r['useful_flop_ratio']:.3f} | {r['mfu']:.3f} |")
    return "\n".join(out)


def skips(tag="baseline"):
    import json, os
    path = os.path.join("experiments/dryrun", f"{tag}_summary.json")
    with open(path) as f:
        rows = json.load(f)
    out = ["| arch | shape | mesh | reason |", "|---|---|---|---|"]
    for r in rows:
        if r.get("status") == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                       f"| {r['reason']} |")
    return "\n".join(out)


if __name__ == "__main__":
    tag = sys.argv[1] if len(sys.argv) > 1 else "baseline"
    print(fmt(tag))
    print()
    print("### Skips\n")
    print(skips(tag))
