"""Federated client-execution engine: legacy loop vs scan/vmap throughput.

The simulator's fleets run *reduced* models, so per-iteration compute is
tiny and the legacy path (one jitted ``step(...)`` dispatch + one
``float(loss)`` host sync per local iteration) is dispatch-bound. The scan
engine compiles the whole H-iteration client run into one program, the
vmap round batches all sync-round clients into one program, and the padded
masked-scan round batches a *heterogeneous* fleet — per-client H^k drawn
from [H_min, H_max] — into one program as well. This bench measures
steady-state local-training steps/sec for all paths (compile excluded via
warmup), reports the speedups, and writes them to ``BENCH_fed_engine.json``
so the trajectory is machine-readable.

    PYTHONPATH=src python -m benchmarks.run fedengine
"""
from __future__ import annotations

import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import fed_engine, fedasync, fedavg, simulator
from repro.data import SyntheticLMDataset, stack_batches
from repro.models import registry
from repro.optim import trainable_mask
from repro.types import FedConfig, ModelConfig

# dispatch-bound regime: the per-step compute of a fleet-scale reduced model
BENCH_CFG = ModelConfig(name="fed-bench-tiny", family="dense", num_layers=1,
                        d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                        vocab_size=64)

ARTIFACT = "BENCH_fed_engine.json"


def _timeit(f, iters=20):
    jax.block_until_ready(f())
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def fed_engine_bench(H: int = 32, n_clients: int = 8,
                     out_json: str | None = ARTIFACT):
    print("\n== fed engine bench (legacy step-loop vs lax.scan / vmap) ==")
    cfg = BENCH_CFG
    fed = FedConfig(num_clients=n_clients, lr=0.01, local_iters_min=1,
                    local_iters_max=3)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticLMDataset(vocab=cfg.vocab_size, seq_len=8, seed=0)
    batches = list(ds.batches(1, H, seed=7))
    stacked = stack_batches(iter(batches))
    mask = trainable_mask(params, fed.trainable)
    rows, report = [], {}

    # -- async client: H local iterations ------------------------------
    step, opt = fedasync.make_client_step(cfg, fed)
    run = fed_engine.make_client_run(cfg, fed)

    def loop_client():
        w, _, _ = fedasync.client_update(params, 0, iter(batches), cfg, fed,
                                         step=step, opt=opt, mask=mask,
                                         num_iters=H)
        return w

    def scan_client():
        w, losses = run(params, stacked, mask=mask)
        float(losses[-1])            # the single host sync the caller pays
        return w

    t_loop = _timeit(loop_client)
    t_scan = _timeit(scan_client)
    speedup = t_loop / t_scan
    rows.append(("fed_client_loop", t_loop / H * 1e6,
                 f"{H / t_loop:.0f}_steps_per_s"))
    rows.append(("fed_client_scan", t_scan / H * 1e6,
                 f"{H / t_scan:.0f}_steps_per_s_speedup={speedup:.2f}x"))
    print(f"  client (H={H}): loop {H / t_loop:7.0f} steps/s | "
          f"scan {H / t_scan:7.0f} steps/s | {speedup:.2f}x")
    report["client"] = {"H": H, "loop_steps_per_s": H / t_loop,
                        "scan_steps_per_s": H / t_scan, "speedup": speedup}

    # -- sync round: n_clients x H_max as one vmap program --------------
    rb = list(ds.batches(1, fed.local_iters_max, seed=11))
    round_engine = fed_engine.make_sync_round(cfg, fed)

    def loop_round():
        g, _ = fedavg.fedavg_round_loop(params,
                                        [iter(rb) for _ in range(n_clients)],
                                        cfg, fed, step=step, opt=opt,
                                        mask=mask)
        return g

    def vmap_round():
        g, _ = fedavg.fedavg_round(params,
                                   [iter(rb) for _ in range(n_clients)],
                                   cfg, fed, engine=round_engine, mask=mask)
        return g

    steps = n_clients * fed.local_iters_max
    t_l = _timeit(loop_round, iters=10)
    t_v = _timeit(vmap_round, iters=10)
    rows.append(("fed_round_loop", t_l / steps * 1e6,
                 f"{steps / t_l:.0f}_steps_per_s"))
    rows.append(("fed_round_vmap", t_v / steps * 1e6,
                 f"{steps / t_v:.0f}_steps_per_s_speedup={t_l / t_v:.2f}x"))
    print(f"  round ({n_clients} clients x H={fed.local_iters_max}): "
          f"loop {steps / t_l:7.0f} steps/s | vmap {steps / t_v:7.0f} "
          f"steps/s | {t_l / t_v:.2f}x")
    report["round_homogeneous"] = {
        "n_clients": n_clients, "H": fed.local_iters_max,
        "loop_steps_per_s": steps / t_l, "vmap_steps_per_s": steps / t_v,
        "speedup": t_l / t_v}

    # -- heterogeneous round: per-client H^k in [H_min, H_max], one padded
    #    masked-scan program (was: per-client fallback loop) -------------
    rng_H = [fed.local_iters_min
             + (k * 7919) % (fed.local_iters_max - fed.local_iters_min + 1)
             for k in range(n_clients)]
    het = [list(ds.batches(1, h, seed=100 + k))
           for k, h in enumerate(rng_H)]
    het_steps = sum(rng_H)

    def loop_het():
        g, _ = fedavg.fedavg_round_loop(params, [iter(b) for b in het],
                                        cfg, fed, step=step, opt=opt,
                                        mask=mask)
        return g

    def padded_het():
        g, _ = fedavg.fedavg_round(params, [iter(b) for b in het],
                                   cfg, fed, engine=round_engine, mask=mask)
        return g

    t_hl = _timeit(loop_het, iters=10)
    t_hp = _timeit(padded_het, iters=10)
    rows.append(("fed_round_het_loop", t_hl / het_steps * 1e6,
                 f"{het_steps / t_hl:.0f}_steps_per_s"))
    rows.append(("fed_round_het_padded", t_hp / het_steps * 1e6,
                 f"{het_steps / t_hp:.0f}_steps_per_s_"
                 f"speedup={t_hl / t_hp:.2f}x"))
    print(f"  het round ({n_clients} clients, H^k={rng_H}): "
          f"loop {het_steps / t_hl:7.0f} steps/s | padded "
          f"{het_steps / t_hp:7.0f} steps/s | {t_hl / t_hp:.2f}x")
    report["round_heterogeneous"] = {
        "n_clients": n_clients, "H_per_client": rng_H,
        "loop_steps_per_s": het_steps / t_hl,
        "padded_steps_per_s": het_steps / t_hp,
        "speedup": t_hl / t_hp}

    # -- async micro-batching window sweep: steady-state receives/s ------
    rows_w, report_w = _window_sweep(cfg, n_clients=n_clients)
    rows.extend(rows_w)
    report["async_window_sweep"] = report_w

    # -- pluggable algorithms through the padded round -------------------
    rows_a, report_a = _algorithm_sweep(cfg, n_clients=n_clients)
    rows.extend(rows_a)
    report["algorithms"] = report_a

    if out_json:
        with open(out_json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"  wrote {out_json}")
        return rows, [out_json]
    return rows


def _window_sweep(cfg: ModelConfig, n_clients: int = 8,
                  epochs: int = 64, windows=(0.0, 120.0, 240.0, 480.0),
                  repeats: int = 3):
    """Steady-state async receive throughput vs the micro-batching window.

    At W=0 every steady-state receive is one ``_mix`` dispatch plus one
    single-client program; a positive W drains receive groups (one fused
    scan mix) and re-dispatches them as one batched program — fewer,
    larger dispatches. The virtual clock is untouched by real execution
    speed, so receives per *real* second is the server-cost metric; the
    staleness histogram records the window's (bounded) shift.

    The sweep runs uniform H (H_min == H_max) to isolate the effect the
    window targets — dispatch amortization, the simulator's actual
    regime — from *padding* waste: with heterogeneous H^k a grouped burst
    pads every client to H_max and spends real compute on masked steps,
    which on CPU-scale models can eat the dispatch savings (that
    trade-off is visible in the het-round rows above; window choice for
    ragged fleets should weigh both).
    """
    assert windows[0] == 0.0, "speedup_vs_window0 normalizes to windows[0]"
    print(f"  async window sweep ({n_clients} clients, {epochs} epochs)")
    fed = FedConfig(num_clients=n_clients, global_epochs=epochs, lr=0.01,
                    local_iters_min=2, local_iters_max=2)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticLMDataset(vocab=cfg.vocab_size, seq_len=8, seed=0)
    fleet = tuple(simulator.JETSON_FLEET_HMDB51[k % 4]
                  for k in range(n_clients))
    batch_lists = [list(ds.batches(1, fed.local_iters_max, seed=50 + k))
                   for k in range(n_clients)]
    data = [lambda k=k: iter(batch_lists[k]) for k in range(n_clients)]

    def run_once(w):
        return simulator.run_async(params, cfg, fed, fleet, data,
                                   engine="scan", window=w)

    # warm every window's compile caches first, keeping each run's hists
    # (runs are deterministic: the warm run sees the same groups)
    results = {w: run_once(w) for w in windows}
    # interleave the timed repeats round-robin so every window samples
    # the same host-load eras, then take per-window minima — back-to-back
    # best-of-N still skews when load drifts on minute timescales
    best = {w: float("inf") for w in windows}
    for _ in range(repeats):
        for w in windows:
            t0 = time.perf_counter()
            run_once(w)
            best[w] = min(best[w], time.perf_counter() - t0)

    rows, report = [], []
    base_rps = epochs / best[windows[0]]
    for w in windows:
        dt, res = best[w], results[w]
        rps = epochs / dt
        mean_group = epochs / max(sum(res.group_hist.values()), 1)
        speedup = rps / base_rps
        rows.append((f"fed_async_window_{w:g}", dt / epochs * 1e6,
                     f"{rps:.0f}_receives_per_s_speedup={speedup:.2f}x"))
        print(f"    W={w:6g}s: {rps:7.0f} receives/s | mean group "
              f"{mean_group:.2f} | staleness {res.staleness_hist}")
        report.append({
            "window_s": w, "receives_per_s": rps,
            "mean_group_size": mean_group,
            "group_hist": {str(k): v
                           for k, v in sorted(res.group_hist.items())},
            "staleness_hist": {str(k): v
                               for k, v in
                               sorted(res.staleness_hist.items())},
            "speedup_vs_window0": speedup})
    return rows, report


def _algorithm_sweep(cfg: ModelConfig, n_clients: int = 8):
    """Pluggable FedAlgorithm layer (core/algorithms.py): round throughput
    and uplink cost per algorithm.

    Throughput: one heterogeneous-H^k padded round (the batched program)
    vs the per-iteration loop oracle — stateful algorithms (SCAFFOLD's
    control variates, the low-rank submodel's capacity state) must keep
    the one-program-per-round-shape property, so their steps/s should sit
    near FedProx's, not near the loop's. Wire: per-round uplink bytes at
    the int8 delta codec (``fed.compress_bits=8``, the matched-width
    comparison) — the low-rank factors are the only payload expected to
    undercut the dense int8 delta.
    """
    import dataclasses

    from repro.core import algorithms, compression

    print(f"  algorithm sweep ({n_clients} clients)")
    fed = FedConfig(num_clients=n_clients, lr=0.01, local_iters_min=1,
                    local_iters_max=3)
    fed8 = dataclasses.replace(fed, compress_bits=8)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticLMDataset(vocab=cfg.vocab_size, seq_len=8, seed=0)
    mask = trainable_mask(params, fed.trainable)
    rng_H = [fed.local_iters_min
             + (k * 7919) % (fed.local_iters_max - fed.local_iters_min + 1)
             for k in range(n_clients)]
    het = [list(ds.batches(1, h, seed=200 + k))
           for k, h in enumerate(rng_H)]
    steps = sum(rng_H)
    dense_f32 = sum(int(np.prod(l.shape)) * 4
                    for l in jax.tree_util.tree_leaves(params))

    rows, report = [], {}
    for name in sorted(algorithms.ALGORITHMS):
        alg = algorithms.make_algorithm(name)

        def padded_round(alg=alg):
            g, _ = fedavg.fedavg_round(params, [iter(b) for b in het],
                                       cfg, fed, mask=mask, algorithm=alg)
            return g

        def loop_round(alg=alg):
            g, _ = fedavg.fedavg_round_loop(params, [iter(b) for b in het],
                                            cfg, fed, mask=mask,
                                            algorithm=alg)
            return g

        t_p = _timeit(padded_round, iters=10)
        t_l = _timeit(loop_round, iters=10)

        # uplink: one client update, encoded at the matched int8 width
        w_new, _, msg, _ = algorithms.client_update_loop(
            params, het[0], cfg, fed8, alg, client_id=0, mask=mask,
            server_ctx=alg.ctx_for(params))
        wire = alg.encode(w_new, msg, params, fed8).wire_bytes
        dense8 = compression.quantize_delta(w_new, params, 8).wire_bytes

        rows.append((f"fed_alg_{name}_padded", t_p / steps * 1e6,
                     f"{steps / t_p:.0f}_steps_per_s_"
                     f"speedup={t_l / t_p:.2f}x_vs_loop"))
        rows.append((f"fed_alg_{name}_wire", float(wire),
                     f"bytes_per_client_int8_"
                     f"ratio={wire / dense8:.3f}_vs_dense_int8"))
        print(f"    {name:8s}: padded {steps / t_p:7.0f} steps/s | loop "
              f"{steps / t_l:7.0f} steps/s | wire {wire} B "
              f"({wire / dense8:.3f}x dense int8)")
        report[name] = {
            "padded_steps_per_s": steps / t_p,
            "loop_steps_per_s": steps / t_l,
            "speedup": t_l / t_p,
            "wire_bytes_per_client_int8": wire,
            "dense_int8_bytes": dense8,
            "dense_f32_bytes": dense_f32,
            "wire_ratio_vs_dense_int8": wire / dense8}
    return rows, report


if __name__ == "__main__":
    fed_engine_bench()
