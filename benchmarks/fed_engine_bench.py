"""Federated client-execution engine: legacy loop vs scan/vmap throughput.

The simulator's fleets run *reduced* models, so per-iteration compute is
tiny and the legacy path (one jitted ``step(...)`` dispatch + one
``float(loss)`` host sync per local iteration) is dispatch-bound. The scan
engine compiles the whole H-iteration client run into one program, the
vmap round batches all sync-round clients into one program, and the padded
masked-scan round batches a *heterogeneous* fleet — per-client H^k drawn
from [H_min, H_max] — into one program as well. This bench measures
steady-state local-training steps/sec for all paths (compile excluded via
warmup), reports the speedups, and writes them to ``BENCH_fed_engine.json``
so the trajectory is machine-readable.

    PYTHONPATH=src python -m benchmarks.run fedengine
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core import fed_engine, fedasync, fedavg
from repro.data import SyntheticLMDataset, stack_batches
from repro.models import registry
from repro.optim import trainable_mask
from repro.types import FedConfig, ModelConfig

# dispatch-bound regime: the per-step compute of a fleet-scale reduced model
BENCH_CFG = ModelConfig(name="fed-bench-tiny", family="dense", num_layers=1,
                        d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                        vocab_size=64)

ARTIFACT = "BENCH_fed_engine.json"


def _timeit(f, iters=20):
    jax.block_until_ready(f())
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def fed_engine_bench(H: int = 32, n_clients: int = 8,
                     out_json: str | None = ARTIFACT):
    print("\n== fed engine bench (legacy step-loop vs lax.scan / vmap) ==")
    cfg = BENCH_CFG
    fed = FedConfig(num_clients=n_clients, lr=0.01, local_iters_min=1,
                    local_iters_max=3)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticLMDataset(vocab=cfg.vocab_size, seq_len=8, seed=0)
    batches = list(ds.batches(1, H, seed=7))
    stacked = stack_batches(iter(batches))
    mask = trainable_mask(params, fed.trainable)
    rows, report = [], {}

    # -- async client: H local iterations ------------------------------
    step, opt = fedasync.make_client_step(cfg, fed)
    run = fed_engine.make_client_run(cfg, fed)

    def loop_client():
        w, _, _ = fedasync.client_update(params, 0, iter(batches), cfg, fed,
                                         step=step, opt=opt, mask=mask,
                                         num_iters=H)
        return w

    def scan_client():
        w, losses = run(params, stacked, mask=mask)
        float(losses[-1])            # the single host sync the caller pays
        return w

    t_loop = _timeit(loop_client)
    t_scan = _timeit(scan_client)
    speedup = t_loop / t_scan
    rows.append(("fed_client_loop", t_loop / H * 1e6,
                 f"{H / t_loop:.0f}_steps_per_s"))
    rows.append(("fed_client_scan", t_scan / H * 1e6,
                 f"{H / t_scan:.0f}_steps_per_s_speedup={speedup:.2f}x"))
    print(f"  client (H={H}): loop {H / t_loop:7.0f} steps/s | "
          f"scan {H / t_scan:7.0f} steps/s | {speedup:.2f}x")
    report["client"] = {"H": H, "loop_steps_per_s": H / t_loop,
                        "scan_steps_per_s": H / t_scan, "speedup": speedup}

    # -- sync round: n_clients x H_max as one vmap program --------------
    rb = list(ds.batches(1, fed.local_iters_max, seed=11))
    round_engine = fed_engine.make_sync_round(cfg, fed)

    def loop_round():
        g, _ = fedavg.fedavg_round_loop(params,
                                        [iter(rb) for _ in range(n_clients)],
                                        cfg, fed, step=step, opt=opt,
                                        mask=mask)
        return g

    def vmap_round():
        g, _ = fedavg.fedavg_round(params,
                                   [iter(rb) for _ in range(n_clients)],
                                   cfg, fed, engine=round_engine, mask=mask)
        return g

    steps = n_clients * fed.local_iters_max
    t_l = _timeit(loop_round, iters=10)
    t_v = _timeit(vmap_round, iters=10)
    rows.append(("fed_round_loop", t_l / steps * 1e6,
                 f"{steps / t_l:.0f}_steps_per_s"))
    rows.append(("fed_round_vmap", t_v / steps * 1e6,
                 f"{steps / t_v:.0f}_steps_per_s_speedup={t_l / t_v:.2f}x"))
    print(f"  round ({n_clients} clients x H={fed.local_iters_max}): "
          f"loop {steps / t_l:7.0f} steps/s | vmap {steps / t_v:7.0f} "
          f"steps/s | {t_l / t_v:.2f}x")
    report["round_homogeneous"] = {
        "n_clients": n_clients, "H": fed.local_iters_max,
        "loop_steps_per_s": steps / t_l, "vmap_steps_per_s": steps / t_v,
        "speedup": t_l / t_v}

    # -- heterogeneous round: per-client H^k in [H_min, H_max], one padded
    #    masked-scan program (was: per-client fallback loop) -------------
    rng_H = [fed.local_iters_min
             + (k * 7919) % (fed.local_iters_max - fed.local_iters_min + 1)
             for k in range(n_clients)]
    het = [list(ds.batches(1, h, seed=100 + k))
           for k, h in enumerate(rng_H)]
    het_steps = sum(rng_H)

    def loop_het():
        g, _ = fedavg.fedavg_round_loop(params, [iter(b) for b in het],
                                        cfg, fed, step=step, opt=opt,
                                        mask=mask)
        return g

    def padded_het():
        g, _ = fedavg.fedavg_round(params, [iter(b) for b in het],
                                   cfg, fed, engine=round_engine, mask=mask)
        return g

    t_hl = _timeit(loop_het, iters=10)
    t_hp = _timeit(padded_het, iters=10)
    rows.append(("fed_round_het_loop", t_hl / het_steps * 1e6,
                 f"{het_steps / t_hl:.0f}_steps_per_s"))
    rows.append(("fed_round_het_padded", t_hp / het_steps * 1e6,
                 f"{het_steps / t_hp:.0f}_steps_per_s_"
                 f"speedup={t_hl / t_hp:.2f}x"))
    print(f"  het round ({n_clients} clients, H^k={rng_H}): "
          f"loop {het_steps / t_hl:7.0f} steps/s | padded "
          f"{het_steps / t_hp:7.0f} steps/s | {t_hl / t_hp:.2f}x")
    report["round_heterogeneous"] = {
        "n_clients": n_clients, "H_per_client": rng_H,
        "loop_steps_per_s": het_steps / t_hl,
        "padded_steps_per_s": het_steps / t_hp,
        "speedup": t_hl / t_hp}

    if out_json:
        with open(out_json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"  wrote {out_json}")
        return rows, [out_json]
    return rows


if __name__ == "__main__":
    fed_engine_bench()
