"""Federated client-execution engine: legacy loop vs scan/vmap throughput.

The simulator's fleets run *reduced* models, so per-iteration compute is
tiny and the legacy path (one jitted ``step(...)`` dispatch + one
``float(loss)`` host sync per local iteration) is dispatch-bound. The scan
engine compiles the whole H-iteration client run into one program and the
vmap round batches all sync-round clients into one program — this bench
measures steady-state local-training steps/sec for both paths (compile
excluded via warmup) and reports the speedup.

    PYTHONPATH=src python -m benchmarks.run fedengine
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import fed_engine, fedasync, fedavg
from repro.data import SyntheticLMDataset, stack_batches
from repro.models import registry
from repro.optim import trainable_mask
from repro.types import FedConfig, ModelConfig

# dispatch-bound regime: the per-step compute of a fleet-scale reduced model
BENCH_CFG = ModelConfig(name="fed-bench-tiny", family="dense", num_layers=1,
                        d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                        vocab_size=64)


def _timeit(f, iters=20):
    jax.block_until_ready(f())
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def fed_engine_bench(H: int = 32, n_clients: int = 8):
    print("\n== fed engine bench (legacy step-loop vs lax.scan / vmap) ==")
    cfg = BENCH_CFG
    fed = FedConfig(num_clients=n_clients, lr=0.01, local_iters_max=3)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticLMDataset(vocab=cfg.vocab_size, seq_len=8, seed=0)
    batches = list(ds.batches(1, H, seed=7))
    stacked = stack_batches(iter(batches))
    mask = trainable_mask(params, fed.trainable)
    rows = []

    # -- async client: H local iterations ------------------------------
    step, opt = fedasync.make_client_step(cfg, fed)
    run = fed_engine.make_client_run(cfg, fed)

    def loop_client():
        w, _, _ = fedasync.client_update(params, 0, iter(batches), cfg, fed,
                                         step=step, opt=opt, mask=mask,
                                         num_iters=H)
        return w

    def scan_client():
        w, losses = run(params, stacked, mask=mask)
        float(losses[-1])            # the single host sync the caller pays
        return w

    t_loop = _timeit(loop_client)
    t_scan = _timeit(scan_client)
    speedup = t_loop / t_scan
    rows.append(("fed_client_loop", t_loop / H * 1e6,
                 f"{H / t_loop:.0f}_steps_per_s"))
    rows.append(("fed_client_scan", t_scan / H * 1e6,
                 f"{H / t_scan:.0f}_steps_per_s_speedup={speedup:.2f}x"))
    print(f"  client (H={H}): loop {H / t_loop:7.0f} steps/s | "
          f"scan {H / t_scan:7.0f} steps/s | {speedup:.2f}x")

    # -- sync round: n_clients x H_max as one vmap program --------------
    rb = list(ds.batches(1, fed.local_iters_max, seed=11))
    round_engine = fed_engine.make_sync_round(cfg, fed)

    def loop_round():
        g, _ = fedavg.fedavg_round_loop(params,
                                        [iter(rb) for _ in range(n_clients)],
                                        cfg, fed, step=step, opt=opt,
                                        mask=mask)
        return g

    def vmap_round():
        g, _ = fedavg.fedavg_round(params,
                                   [iter(rb) for _ in range(n_clients)],
                                   cfg, fed, engine=round_engine, mask=mask)
        return g

    steps = n_clients * fed.local_iters_max
    t_l = _timeit(loop_round, iters=10)
    t_v = _timeit(vmap_round, iters=10)
    rows.append(("fed_round_loop", t_l / steps * 1e6,
                 f"{steps / t_l:.0f}_steps_per_s"))
    rows.append(("fed_round_vmap", t_v / steps * 1e6,
                 f"{steps / t_v:.0f}_steps_per_s_speedup={t_l / t_v:.2f}x"))
    print(f"  round ({n_clients} clients x H={fed.local_iters_max}): "
          f"loop {steps / t_l:7.0f} steps/s | vmap {steps / t_v:7.0f} "
          f"steps/s | {t_l / t_v:.2f}x")
    return rows


if __name__ == "__main__":
    fed_engine_bench()
