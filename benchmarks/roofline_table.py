"""Roofline table from the dry-run artifacts (experiments/dryrun/*.json).

Prints per (arch × shape × mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs and MFU at the roofline step time.
"""
from __future__ import annotations

import glob
import json
import os


def load_rows(out_dir="experiments/dryrun", tag="baseline"):
    rows = []
    summary = os.path.join(out_dir, f"{tag}_summary.json")
    if os.path.exists(summary):
        with open(summary) as f:
            return [r for r in json.load(f) if r.get("status") == "OK"]
    for f in sorted(glob.glob(os.path.join(out_dir, f"{tag}_*.json"))):
        if f.endswith("_summary.json"):
            continue
        with open(f) as fh:
            rows.append(json.load(fh))
    return rows


def roofline_table(tag="baseline", out_dir="experiments/dryrun"):
    rows = load_rows(out_dir, tag)
    out = []
    if not rows:
        print(f"  (no dry-run artifacts with tag {tag!r} — run "
              f"PYTHONPATH=src python -m repro.launch.dryrun first)")
        return out
    print(f"\n== Roofline ({tag}): compute / memory / collective per step ==")
    print(f"  {'arch':26s} {'shape':12s} {'mesh':8s} "
          f"{'comp_ms':>8s} {'mem_ms':>8s} {'coll_ms':>8s} "
          f"{'dominant':>10s} {'peakGiB':>8s} {'MFU':>6s}")
    for r in rows:
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        print(f"  {r['arch']:26s} {r['shape']:12s} {r['mesh']:8s} "
              f"{r['compute_s']*1e3:8.2f} {r['memory_s']*1e3:8.2f} "
              f"{r['collective_s']*1e3:8.2f} {r['dominant']:>10s} "
              f"{r['peak_memory_bytes']/2**30:8.2f} {r['mfu']:6.3f}")
        out.append((name, r["step_time_s"] * 1e6,
                    f"dom={r['dominant']};mfu={r['mfu']:.3f}"))
    return out
