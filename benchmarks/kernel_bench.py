"""Kernel micro-benchmarks: fused KD loss vs unfused jnp reference, and the
jnp model-attention path vs the Pallas SWA kernel's work ratio.

On CPU the Pallas kernels run in interpret mode (Python per grid step), so
wall-clock comparisons against jnp are meaningless; what IS meaningful here
is (a) wall time of the *jnp oracle* paths the model actually runs on this
host and (b) the analytic HBM-traffic ratio of fused vs unfused KD loss —
the quantity the kernel exists to improve on TPU.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _timeit(f, *args, iters=5):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def kernel_bench():
    print("\n== kernel benches (jnp oracle wall time; fused-vs-unfused "
          "HBM traffic model) ==")
    rows = []
    rng = np.random.default_rng(0)

    # KD loss: unfused = 2 reads of s (lse, gather+sq) + 1 read of t + CE
    R, V = 512, 4096
    s = jnp.asarray(rng.standard_normal((R, V)), jnp.float32)
    t = jnp.asarray(rng.standard_normal((R, V)), jnp.float32)
    lab = jnp.asarray(rng.integers(0, V, R), jnp.int32)
    jref = jax.jit(lambda a, b, l: ref.kd_loss_ref(a, b, l, 0.5))
    dt = _timeit(jref, s, t, lab)
    # unfused traffic: s read 3x (max, sumexp, sq) + t 1x; fused: s 1x t 1x
    unfused = 4 * R * V * 4
    fused = 2 * R * V * 4
    rows.append(("kernel_kd_loss_ref", dt * 1e6,
                 f"hbm_fused/unfused={fused/unfused:.2f}"))
    print(f"  kd_loss oracle ({R}x{V}): {dt*1e3:.2f} ms; fused kernel "
          f"reads {fused/unfused:.0%} of unfused HBM traffic")

    # SWA: work ratio of windowed kernel vs full attention at 32k/window 1k
    S, w = 32768, 1024
    q_blocks = S // 128
    full_tiles = sum(i + 1 for i in range(q_blocks))
    import math
    win_tiles = q_blocks * (math.ceil((w + 128) / 128) + 1)
    rows.append(("kernel_swa_tile_ratio", 0.0,
                 f"windowed/full={win_tiles/full_tiles:.4f}"))
    print(f"  swa kernel tiles at S={S}, w={w}: {win_tiles} vs {full_tiles} "
          f"({win_tiles/full_tiles:.1%} of full-attention tiles)")

    # SSD: oracle wall time per token at model scale (mamba2-130m shapes)
    B, Sq, H, P, N = 1, 2048, 24, 64, 128
    x = jnp.asarray(rng.standard_normal((B, Sq, H, P)), jnp.float32)
    dtv = jax.nn.softplus(jnp.asarray(rng.standard_normal((B, Sq, H)),
                                      jnp.float32))
    A = -jnp.exp(jnp.asarray(rng.standard_normal(H) * 0.3, jnp.float32))
    Bm = jnp.asarray(rng.standard_normal((B, Sq, N)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B, Sq, N)) * 0.5, jnp.float32)
    jssd = jax.jit(lambda *a: ref.ssd_scan_ref(*a, chunk=256)[0])
    dt = _timeit(jssd, x, dtv, A, Bm, Cm, iters=3)
    rows.append(("kernel_ssd_ref_2k", dt * 1e6,
                 f"{B*Sq/dt:.0f}_tok_per_s_host"))
    print(f"  ssd oracle (S=2048, mamba2-130m layer): {dt*1e3:.2f} ms "
          f"({B*Sq/dt:.0f} tok/s on host)")

    # fused decode kernels (PR 7): modeled HBM bytes per decode step vs the
    # einsum path they replace — at gemma3-12b attend and mamba2-130m SSD
    # shapes.  Decode is memory-bound, so the byte ratio IS the speedup
    # ceiling on TPU; wall time in interpret mode would measure Python.
    from repro.roofline.analysis import attend_decode_bytes, ssd_decode_bytes
    kv, heads, hd, n_ctx = 8, 16, 256, 1024      # gemma3-12b, 1k context
    af = attend_decode_bytes(n_ctx, kv, heads, hd)
    au = attend_decode_bytes(n_ctx, kv, heads, hd, fused=False)
    rows.append(("kernel_decode_attend_bytes", 0.0,
                 f"hbm_fused/einsum={af/au:.2f}"))
    print(f"  decode attend (gemma3-12b heads, n_ctx={n_ctx}): fused reads "
          f"{af/au:.0%} of einsum HBM bytes/step")
    H, P, N = 24, 64, 128                        # mamba2-130m layer
    sf = ssd_decode_bytes(H, P, N)
    su = ssd_decode_bytes(H, P, N, fused=False)
    rows.append(("kernel_decode_ssd_bytes", 0.0,
                 f"hbm_fused/einsum={sf/su:.2f}"))
    print(f"  decode ssd (mamba2-130m layer): fused reads {sf/su:.0%} of "
          f"einsum HBM bytes/step")
    return rows
