"""One benchmark per paper table (I–V) + the hyperparameter sweeps
(Figs. 9–12). Each function returns a list of CSV rows
(name, us_per_call, derived) and prints a human-readable block.

Time accounting notes (see EXPERIMENTS.md §Tables):
- Table II async wall-clock follows the paper's accounting: the run ends
  when every client has delivered its E/n quota, so the slowest client
  gates — this reproduces the paper's 6h31m (HMDB51) to within rounding.
- The paper's *synchronous* rounds carry a measured coordination overhead
  (barrier + 4-way model upload contention). Back-solving Table II gives
  overhead ≈ 0.67× round compute on BOTH datasets (0.672 HMDB51, 0.660
  UCF101) — we use SYNC_OVERHEAD_FRAC = 0.67 and report the fit.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import RESNET18, RESNET26, RESNET34, get_config
from repro.configs.resnet3d import BLOCKS
from repro.core import distill, simulator
from repro.core.simulator import (JETSON_FLEET_HMDB51, JETSON_FLEET_UCF101)
from repro.data import BatchLoader, SyntheticActionDataset, iid_partition
from repro.models import registry
from repro.types import DistillConfig, FedConfig, ModelConfig

SYNC_OVERHEAD_FRAC = 0.67   # fitted from paper Table II (see module doc)
LOCAL_EPOCHS = 3            # paper §V-B
GLOBAL_EPOCHS = 80          # paper Table II


def _fmt_h(s: float) -> str:
    h = int(s // 3600)
    m = int((s % 3600) // 60)
    return f"{h}h{m:02d}m"


def _mk(name):
    import dataclasses
    from repro.configs.resnet3d import KINETICS_CLASSES
    depth = 2 + 2 * sum(BLOCKS[name])
    return ModelConfig(name=name, family="resnet3d", num_layers=depth,
                       d_model=64, num_heads=0, num_kv_heads=0, d_ff=0,
                       vocab_size=KINETICS_CLASSES,
                       num_classes=KINETICS_CLASSES, source="paper §V-A")


# ---------------------------------------------------------------------------
# Table I — KD with 0/1/2/3 TAs: time grows sharply, accuracy saturates
# ---------------------------------------------------------------------------

def table1_kd_tas():
    print("\n== Table I: knowledge distillation vs number of TAs ==")
    chains = {
        0: [RESNET34, RESNET18],
        1: [RESNET34, RESNET26, RESNET18],
        2: [RESNET34, _mk("resnet3d-28"), _mk("resnet3d-24"), RESNET18],
        3: [RESNET34, _mk("resnet3d-30"), RESNET26, _mk("resnet3d-22"),
            RESNET18],
    }
    paper_time = {0: "44h58m (+0%)", 1: "55h23m (+23.2%)",
                  2: "69h35m (+54.7%)", 3: "85h47m (+90.8%)"}
    paper_acc = {0: 53.8, 1: 54.6, 2: 54.8, 3: 54.9}
    # FLOPs-proportional full-scale time model (Kinetics: 306k clips/epoch)
    rows = []
    t0 = None
    for n_tas, chain in chains.items():
        pred = distill.chain_time_model(chain, dataset_items=306_245,
                                        epochs=200)
        if t0 is None:
            t0 = pred["total_s"]
        inc = 100.0 * (pred["total_s"] / t0 - 1.0)
        print(f"  {n_tas} TAs: predicted {_fmt_h(pred['total_s'])} "
              f"(+{inc:.1f}%)   [paper: {paper_time[n_tas]}, "
              f"per-clip acc {paper_acc[n_tas]}%]")
        rows.append((f"table1_kd_{n_tas}tas", pred["total_s"] * 1e6,
                     f"+{inc:.1f}%_vs_0tas"))
    # smoke-scale accuracy trend: 1 TA >= no TA (measured)
    ds = SyntheticActionDataset(num_classes=8, samples_per_class=32,
                                noise=0.35, seed=0)
    loader = BatchLoader(ds, 8, steps=20, seed=0)
    eval_b = list(ds.batches(8, 6, seed=99))
    dcfg = DistillConfig(alpha=0.5, lr=0.02)
    accs = {}
    for n_tas, chain in list(chains.items())[:2]:
        rchain = [c.reduced() for c in chain]
        t_start = time.perf_counter()
        _, stages = distill.run_chain(rchain, dcfg, loader, eval_b,
                                      steps_per_stage=20, seed=0,
                                      trained_teacher_steps=20)
        accs[n_tas] = stages[-1].accuracy
        rows.append((f"table1_smoke_{n_tas}tas_acc",
                     (time.perf_counter() - t_start) * 1e6,
                     f"acc={stages[-1].accuracy:.3f}"))
    print(f"  smoke-scale student accuracy: no-TA {accs[0]:.3f}, "
          f"1-TA {accs[1]:.3f} (paper trend: TA >= no-TA)")
    return rows


# ---------------------------------------------------------------------------
# Table II — stage wall-times (KD / fine-tune central / sync / async)
# ---------------------------------------------------------------------------

def _table2_times(fleet, epochs=GLOBAL_EPOCHS, H=LOCAL_EPOCHS):
    n = len(fleet)
    rounds = epochs / n
    per_update = [p.epoch_seconds * H for p in fleet]
    async_s = rounds * max(per_update)            # slowest client's quota
    sync_s = rounds * max(per_update) * (1 + SYNC_OVERHEAD_FRAC)
    return sync_s, async_s


def table2_stage_times():
    print("\n== Table II: stage wall-times (simulated fleet) ==")
    paper = {
        ("HMDB51", "sync"): 10 * 3600 + 54 * 60,
        ("HMDB51", "async"): 6 * 3600 + 31 * 60,
        ("UCF101", "sync"): 74 * 3600 + 27 * 60,
        ("UCF101", "async"): 44 * 3600 + 7 * 60,
    }
    rows = []
    for name, fleet in (("HMDB51", JETSON_FLEET_HMDB51),
                        ("UCF101", JETSON_FLEET_UCF101)):
        sync_s, async_s = _table2_times(fleet)
        red = 1 - async_s / sync_s
        for kind, ours in (("sync", sync_s), ("async", async_s)):
            ref = paper[(name, kind)]
            err = 100 * (ours - ref) / ref
            print(f"  {name:7s} {kind:5s}: {_fmt_h(ours)} "
                  f"(paper {_fmt_h(ref)}, {err:+.1f}%)")
            rows.append((f"table2_{name}_{kind}", ours * 1e6,
                         f"paper_err={err:+.1f}%"))
        print(f"  {name:7s} async reduction: {100*red:.1f}% "
              f"(paper claims ~40%)")
        rows.append((f"table2_{name}_reduction", 0.0, f"{100*red:.1f}%"))
    return rows


# ---------------------------------------------------------------------------
# Table III — per-clip / per-video accuracy, central vs sync vs async
# ---------------------------------------------------------------------------

def _per_video_acc(params, cfg, ds, n_videos=16, clips_per_video=4,
                   seed=123):
    """Paper metric: mean of class scores over a video's clips."""
    rng = np.random.default_rng(seed)
    hits_clip = hits_video = tot_clips = 0
    import functools
    logits_j = jax.jit(functools.partial(registry.logits_fn, cfg=cfg))
    for _ in range(n_videos):
        c = int(rng.integers(0, ds.num_classes))
        clips = np.stack([ds.render(c, rng) for _ in range(clips_per_video)])
        logits = logits_j(params=params,
                          batch={"clips": jnp.asarray(clips)})
        pred_clips = np.asarray(jnp.argmax(logits, axis=-1))
        hits_clip += int((pred_clips == c).sum())
        tot_clips += clips_per_video
        if int(np.argmax(np.asarray(logits).mean(axis=0))) == c:
            hits_video += 1
    return hits_clip / tot_clips, hits_video / n_videos


def table3_accuracy():
    print("\n== Table III: per-clip / per-video accuracy "
          "(smoke scale, synthetic HMDB51 stand-in) ==")
    cfg = RESNET18.reduced()
    params0 = registry.init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticActionDataset(num_classes=8, samples_per_class=16,
                                noise=0.4, seed=2)
    fed = FedConfig(num_clients=4, global_epochs=24, local_iters_min=1,
                    local_iters_max=3, lr=0.05, trainable="all")
    parts = iid_partition(len(ds), 4)
    data = [BatchLoader(ds, 8, steps=4, seed=k, indices=parts[k])
            for k in range(4)]
    rows = []

    # central baseline
    from repro.core.fedasync import make_client_step
    from repro.optim import trainable_mask
    step, opt = make_client_step(cfg, fed)
    mask = trainable_mask(params0, "all")
    p, st = params0, opt.init(params0)
    for i, b in enumerate(ds.batches(8, 24, seed=0)):
        p, st, _ = step(p, st, params0, b, mask)
    central = p

    res_sync = simulator.run_sync(params0, cfg, fed, JETSON_FLEET_HMDB51,
                                  data)
    res_async = simulator.run_async(params0, cfg, fed, JETSON_FLEET_HMDB51,
                                    data)
    paper = {"central": (57.3, 64.1), "sync": (54.4, 61.8),
             "async": (55.6, 62.3)}
    for name, params in (("central", central), ("sync", res_sync.params),
                         ("async", res_async.params)):
        t0 = time.perf_counter()
        clip, video = _per_video_acc(params, cfg, ds)
        dt = (time.perf_counter() - t0) * 1e6
        pc, pv = paper[name]
        print(f"  {name:8s}: per-clip {clip:.3f} per-video {video:.3f} "
              f"(paper full-scale: {pc}% / {pv}%)")
        rows.append((f"table3_{name}", dt,
                     f"clip={clip:.3f};video={video:.3f}"))
    # paper invariant: per-video >= per-clip (score averaging denoises)
    return rows


# ---------------------------------------------------------------------------
# Table IV / V — per-device train & inference times
# ---------------------------------------------------------------------------

def _host_step_time(cfg, train=True, iters=3):
    rng = np.random.default_rng(0)
    from repro.types import ShapeConfig
    shape = ShapeConfig("bench", seq_len=64, global_batch=4, kind="train")
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    batch = registry.synth_batch(rng, cfg, shape)
    if train:
        from repro.core.fedasync import make_client_step
        from repro.optim import trainable_mask
        step, opt = make_client_step(cfg, FedConfig())
        mask = trainable_mask(params, "all")
        st = opt.init(params)
        step(params, st, params, batch, mask)          # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            p, st, _ = step(params, st, params, batch, mask)
        jax.block_until_ready(p)
    else:
        import functools
        f = jax.jit(functools.partial(registry.logits_fn, cfg=cfg))
        f(params=params, batch=batch)                  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = f(params=params, batch=batch)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def table4_device_times():
    print("\n== Table IV: per-local-epoch train time per device "
          "(paper-measured profiles; host-measured reduced model) ==")
    rows = []
    for dsname, fleet in (("HMDB51", JETSON_FLEET_HMDB51),
                          ("UCF101", JETSON_FLEET_UCF101)):
        for p in fleet:
            print(f"  {dsname:7s} {p.name:18s} {p.epoch_seconds:8.1f} s")
            rows.append((f"table4_{dsname}_{p.name}",
                         p.epoch_seconds * 1e6, "paper_profile"))
    host = _host_step_time(RESNET18.reduced(), train=True)
    print(f"  host (reduced resnet3d-18, 4-clip step): {host*1e3:.1f} ms")
    rows.append(("table4_host_reduced_step", host * 1e6, "measured"))
    return rows


def table5_inference():
    print("\n== Table V: test-set inference time per device ==")
    rows = []
    for dsname, fleet in (("HMDB51", JETSON_FLEET_HMDB51),
                          ("UCF101", JETSON_FLEET_UCF101)):
        for p in fleet:
            print(f"  {dsname:7s} {p.name:18s} {p.test_seconds:8.1f} s")
            rows.append((f"table5_{dsname}_{p.name}",
                         p.test_seconds * 1e6, "paper_profile"))
    host = _host_step_time(RESNET18.reduced(), train=False)
    print(f"  host (reduced resnet3d-18, 4-clip fwd): {host*1e3:.1f} ms")
    rows.append(("table5_host_reduced_fwd", host * 1e6, "measured"))
    return rows


# ---------------------------------------------------------------------------
# Figs. 9–12 — staleness exponent a and mixing β sweeps
# ---------------------------------------------------------------------------

def hyperparam_sweep(quick=True):
    print("\n== Figs. 9-12: async hyperparameter sweeps "
          "(smoke scale; paper best: a=0.5, beta=0.7) ==")
    cfg = RESNET18.reduced()
    params0 = registry.init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticActionDataset(num_classes=8, samples_per_class=16,
                                noise=0.4, seed=4)
    parts = iid_partition(len(ds), 4)
    rows = []

    def run(a, beta):
        fed = FedConfig(num_clients=4, global_epochs=16, local_iters_min=1,
                        local_iters_max=3, lr=0.05, mixing_beta=beta,
                        staleness_a=a, trainable="all")
        data = [BatchLoader(ds, 8, steps=4, seed=k, indices=parts[k])
                for k in range(4)]
        res = simulator.run_async(params0, cfg, fed, JETSON_FLEET_HMDB51,
                                  data)
        tail = [l for _, _, l in res.history[-6:]]
        return float(np.mean(tail))

    a_vals = [0.0, 0.5, 0.9] if quick else [0.0, 0.3, 0.5, 0.9]
    for a in a_vals:
        t0 = time.perf_counter()
        loss = run(a, 0.7)
        rows.append((f"sweep_a_{a}", (time.perf_counter() - t0) * 1e6,
                     f"tail_loss={loss:.4f}"))
        print(f"  beta=0.7 a={a}: tail loss {loss:.4f}")
    b_vals = [0.3, 0.7, 0.9] if quick else [0.3, 0.5, 0.7, 0.9]
    for b in b_vals:
        t0 = time.perf_counter()
        loss = run(0.5, b)
        rows.append((f"sweep_beta_{b}", (time.perf_counter() - t0) * 1e6,
                     f"tail_loss={loss:.4f}"))
        print(f"  a=0.5 beta={b}: tail loss {loss:.4f}")
    return rows


# ---------------------------------------------------------------------------
# Beyond-paper: non-IID (Dirichlet) clients — the paper's named future work
# ---------------------------------------------------------------------------

def noniid_extension(quick=True):
    """Async FL under Dirichlet label skew vs IID — the paper's §VI future
    work ('how to handle non-iid data at the different clients')."""
    print("\n== beyond-paper: non-IID (Dirichlet) vs IID clients ==")
    from repro.data import dirichlet_partition
    cfg = RESNET18.reduced()
    params0 = registry.init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticActionDataset(num_classes=8, samples_per_class=16,
                                noise=0.4, seed=6)
    labels = np.repeat(np.arange(ds.num_classes), ds.samples_per_class)
    fed = FedConfig(num_clients=4, global_epochs=16, local_iters_min=1,
                    local_iters_max=3, lr=0.05, prox_theta=0.05,
                    trainable="all")
    rows = []
    for name, parts in (
            ("iid", iid_partition(len(ds), 4)),
            ("dirichlet_0.5", dirichlet_partition(labels, 4, 0.5, seed=0)),
            ("dirichlet_0.1", dirichlet_partition(labels, 4, 0.1, seed=0))):
        data = [BatchLoader(ds, 8, steps=4, seed=k, indices=parts[k])
                for k in range(4)]
        t0 = time.perf_counter()
        res = simulator.run_async(params0, cfg, fed, JETSON_FLEET_HMDB51,
                                  data)
        tail = float(np.mean([l for _, _, l in res.history[-6:]]))
        rows.append((f"noniid_{name}", (time.perf_counter() - t0) * 1e6,
                     f"tail_loss={tail:.4f}"))
        print(f"  {name:15s}: tail loss {tail:.4f} "
              f"(θ-proximal term damps client drift)")
    return rows
