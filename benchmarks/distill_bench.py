"""Distillation engine: per-step loop vs scanned epoch, fused vs eager KD.

Stage 1 of the paper trains the student for hundreds of epochs on the
full dataset — on an embedded-adjacent host the per-step dispatch + host
sync is the tax (same story as the fed engine's per-iteration loop).
This bench drives the same KD workload twice through
``core/distill.py``: the per-step oracle (``DistillEngine.step`` +
``float(loss)`` every step — one dispatch and one device->host sync per
step) vs the scan-compiled epoch (one dispatch, one loss-vector read per
epoch), then times the fused Pallas KD row-loss against its eager jnp
oracle at training-sized row counts. Codistillation compile scaling
(programs grow with distinct architectures, not members) lands in the
same artifact.

    PYTHONPATH=src python -m benchmarks.run distill
    PYTHONPATH=src python -m benchmarks.distill_bench --smoke   # CI shapes
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import RESNET18, RESNET34
from repro.core import distill
from repro.data import BatchLoader, make_dataset_for, stack_batches
from repro.kernels import ops, ref
from repro.types import DistillConfig

ARTIFACT = "BENCH_distill.json"


def _loop_epoch(engine, t_params, params, opt_state, batches):
    """The per-step baseline: dispatch + host sync every step."""
    losses = []
    for batch in batches:
        params, opt_state, loss = engine.step(t_params, params, opt_state,
                                              batch)
        losses.append(float(loss))  # repro-lint: disable=R2
    return params, opt_state, losses


def _time_kd(fn, iters: int) -> float:
    fn()                                      # compile / warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def distill_bench(smoke: bool | None = None,
                  out_json: str | None = ARTIFACT):
    """Scanned KD epoch vs per-step loop + fused vs eager KD loss
    (writes BENCH_distill.json)."""
    if smoke is None:
        smoke = "--smoke" in sys.argv[1:]
    print("\n== distill bench (scan epoch vs per-step loop) ==")
    tcfg, scfg = RESNET34.reduced(), RESNET18.reduced()
    # full-shape kd row counts stay modest: the fused kernel runs in
    # interpret mode on CPU (pure emulation, ~ms/row-block), so big
    # row×iter products only time the emulator
    steps, batch, kd_iters, rows = (8, 2, 20, 256) if smoke \
        else (32, 4, 10, 1024)
    dcfg = DistillConfig(lr=0.01, batch_size=batch)
    ds = make_dataset_for(scfg, small=True, seed=0)
    loader = BatchLoader(ds, batch, steps=steps, seed=0)

    key = jax.random.PRNGKey(0)
    from repro.models import registry
    t_params = registry.init_params(key, tcfg)
    engine = distill.DistillEngine(tcfg, scfg, dcfg)

    # -- per-step loop (compile once on the first step, sync every step) --
    params0 = registry.init_params(jax.random.fold_in(key, 1), scfg)
    opt0 = engine.opt.init(params0)
    batches = list(loader())
    t0 = time.perf_counter()
    _loop_epoch(engine, t_params, params0, opt0, batches)
    loop_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    _loop_epoch(engine, t_params, params0, opt0, batches)
    loop_warm = time.perf_counter() - t0

    # -- scanned epoch (one dispatch, one loss-vector sync) --
    stacked = stack_batches(iter(loader()), limit=steps)
    t0 = time.perf_counter()
    p, o, ls = engine.epoch(t_params, params0, opt0, stacked)
    jax.block_until_ready(ls)
    scan_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    p, o, ls = engine.epoch(t_params, params0, opt0, stacked)
    jax.block_until_ready(ls)
    scan_warm = time.perf_counter() - t0

    # -- fused Pallas KD rows vs eager oracle at training row counts --
    V = 512
    rng = np.random.default_rng(0)
    s = jnp.asarray(rng.standard_normal((rows, V), dtype=np.float32))
    t = jnp.asarray(rng.standard_normal((rows, V), dtype=np.float32))
    lab = jnp.asarray(rng.integers(0, V, (rows,), dtype=np.int32))
    fused_us = _time_kd(
        lambda: ops.kd_loss_rows(s, t, lab, 0.5, temperature=2.0), kd_iters)
    eager_us = _time_kd(
        lambda: ref.kd_loss_ref(s, t, lab, 0.5, temperature=2.0), kd_iters)

    # -- codistill compile scaling: 4 members, 2 architectures --
    fleet = distill.CodistillFleet([scfg, scfg, tcfg, tcfg], dcfg).init(
        jax.random.PRNGKey(2))
    probe = stack_batches(iter(loader()), limit=min(4, steps))
    fleet.round(probe)
    co_compiles = fleet.num_compiled
    n0 = co_compiles
    fleet.round(probe)                        # warm round
    co_warm_new = fleet.num_compiled - n0

    report = {
        "config": {"teacher": tcfg.name, "student": scfg.name,
                   "steps": steps, "batch": batch, "kd_rows": rows,
                   "smoke": smoke},
        "epoch": {"loop_cold_s": loop_cold, "loop_warm_s": loop_warm,
                  "scan_cold_s": scan_cold, "scan_warm_s": scan_warm,
                  "loop_steps_per_s": steps / max(loop_warm, 1e-9),
                  "scan_steps_per_s": steps / max(scan_warm, 1e-9),
                  "warm_speedup": loop_warm / max(scan_warm, 1e-9),
                  "engine_compiles": engine.num_compiled},
        "kd_loss": {"fused_us": fused_us, "eager_us": eager_us,
                    "note": "interpret-mode wall clock on CPU; the fused "
                            "kernel's win is single-pass VMEM traffic on "
                            "TPU (see kernel_bench roofline)"},
        "codistill": {"members": 4, "architectures": 2,
                      "cold_compiles": co_compiles,
                      "warm_round_new_compiles": co_warm_new},
    }
    rows_out = [
        ("distill_loop_epoch", loop_warm * 1e6,
         f"{report['epoch']['loop_steps_per_s']:.1f} steps/s, "
         f"{steps} dispatch+sync"),
        ("distill_scan_epoch", scan_warm * 1e6,
         f"{report['epoch']['scan_steps_per_s']:.1f} steps/s, 1 dispatch "
         f"({report['epoch']['warm_speedup']:.1f}x warm)"),
        ("kd_rows_fused", fused_us, f"{rows}x{V} rows, pallas"),
        ("kd_rows_eager", eager_us, f"{rows}x{V} rows, jnp oracle"),
        ("codistill_round", 0.0,
         f"{co_compiles} compiles for 4 members/2 archs; "
         f"+{co_warm_new} warm"),
    ]
    for name, us, derived in rows_out:
        print(f"  {name}: {us / 1e6:.3f}s — {derived}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        return rows_out, [out_json]
    return rows_out


if __name__ == "__main__":
    distill_bench(smoke="--smoke" in sys.argv[1:])
