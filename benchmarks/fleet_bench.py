"""Streaming fleets: resident state vs population, flat vs hierarchical.

The PR's memory-model claim (core/fleet.py): a ``FleetSpec`` fleet holds
client state only for the sampled / in-flight set, so the resident
footprint is O(m), flat in the population — a 10^6-client fleet costs
the same handful of materialized clients as a 10^3 one. This bench
sweeps the population at fixed per-round sample size m and records the
``max_resident`` / ``max_inflight`` high-water marks plus fleet
construction and round wall-clock (both must stay population-flat), then
times the sampled sync round through the flat 1-D psum engine vs the
two-level ``('edge','clients')`` hierarchical edge-aggregator tree —
same weighted average (the fleet property tests pin equality), different
reduction topology.

``--smoke`` runs the CI shapes and HARD-FAILS if the 10^6-population
round materializes more than the sampled set (the O(sampled) guarantee
this PR ships).

    PYTHONPATH=src python -m benchmarks.run fleet
    PYTHONPATH=src python -m benchmarks.fleet_bench --smoke
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

import jax

from repro.core import fedavg, simulator
from repro.core.fleet import (Fleet, FleetSpec, JETSON_FLEET_HMDB51)
from repro.data import SyntheticLMDataset
from repro.models import registry
from repro.types import FedConfig, ModelConfig

# dispatch-bound regime, same as fed_engine_bench: fleet-scale models are
# reduced, so the interesting costs are materialization and aggregation
BENCH_CFG = ModelConfig(name="fleet-bench-tiny", family="dense",
                        num_layers=1, d_model=32, num_heads=2,
                        num_kv_heads=2, d_ff=64, vocab_size=64)

ARTIFACT = "BENCH_fleet.json"


def _spec(population: int, ds) -> FleetSpec:
    return FleetSpec(population=population, profiles=JETSON_FLEET_HMDB51,
                     dataset=ds, batch_size=2, steps=4, partition="shared")


def _timeit(f, iters: int):
    f()                                       # warm / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f()
    jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
    return (time.perf_counter() - t0) / iters


def fleet_bench(smoke: bool | None = None,
                out_json: str | None = ARTIFACT):
    """Resident state vs population + flat vs hierarchical round timing."""
    if smoke is None:
        smoke = "--smoke" in sys.argv[1:]
    print("\n== fleet bench (streaming populations, sampled rounds) ==")
    cfg = BENCH_CFG
    m = 4 if smoke else 8
    populations = [10**3, 10**6] if smoke else [10**3, 10**4, 10**5, 10**6]
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticLMDataset(vocab=cfg.vocab_size, seq_len=8, seed=0)
    rows, sweep = [], []

    # -- resident state + round wall-clock vs population ----------------
    for pop in populations:
        fed = FedConfig(num_clients=pop, clients_per_round=m,
                        global_epochs=2 * m, lr=0.01, local_iters_min=1,
                        local_iters_max=3)
        t0 = time.perf_counter()
        fleet = Fleet.from_spec(_spec(pop, ds))
        build_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = simulator.run_sync(params, cfg, fed, fleet)
        sync_s = time.perf_counter() - t0
        afleet = Fleet.from_spec(_spec(pop, ds))
        t0 = time.perf_counter()
        ares = simulator.run_async(params, cfg, fed, afleet)
        async_s = time.perf_counter() - t0
        entry = {"population": pop, "sampled_m": m,
                 "build_s": build_s,
                 "sync_rounds": len(res.history), "sync_s": sync_s,
                 "sync_max_resident": fleet.max_resident,
                 "async_epochs": len(ares.history), "async_s": async_s,
                 "async_max_resident": afleet.max_resident,
                 "async_max_inflight": ares.max_inflight}
        sweep.append(entry)
        print(f"  pop={pop:>9,}: resident sync={fleet.max_resident} "
              f"async={afleet.max_resident} inflight={ares.max_inflight} "
              f"(m={m}), sync {sync_s:.2f}s async {async_s:.2f}s")
        if fleet.max_resident > m or afleet.max_resident > m \
                or ares.max_inflight > m:
            raise RuntimeError(
                f"O(sampled) violated at population {pop}: "
                f"sync resident {fleet.max_resident}, async resident "
                f"{afleet.max_resident}, inflight {ares.max_inflight} "
                f"> m={m}")
    big = sweep[-1]
    rows.append(("fleet_resident_1e6", big["sync_s"] * 1e6,
                 f"max_resident {big['sync_max_resident']} of "
                 f"{big['population']:,} (m={m})"))

    # -- sampled-round throughput: flat psum vs hierarchical tree -------
    fed = FedConfig(num_clients=m, lr=0.01, local_iters_min=1,
                    local_iters_max=3)
    iters = 5 if smoke else 20
    spec = _spec(m, ds)
    fleet = Fleet.from_spec(spec)
    timing = {}
    for eng in ("scan", "shard", "hier"):
        batches = [list(fleet.data(k)()) for k in range(m)]
        t = _timeit(lambda: fedavg.fedavg_round(
            params, [iter(b) for b in batches], cfg, fed, engine=eng)[0],
            iters)
        timing[eng] = t
        rows.append((f"fleet_round_{eng}", t * 1e6,
                     f"m={m} sampled sync round, engine={eng}"))
        print(f"  round engine={eng}: {t * 1e3:.2f} ms "
              f"({len(jax.devices())} device(s))")

    report = {
        "config": {"model": cfg.name, "sampled_m": m, "smoke": smoke,
                   "devices": len(jax.devices())},
        "resident_vs_population": sweep,
        "round_seconds": timing,
        "note": "resident/in-flight high-water marks must be flat in the "
                "population (O(sampled) streaming contract); flat vs "
                "hier is the same weighted average through a 1-D psum "
                "vs the ('edge','clients') aggregator tree",
    }
    if out_json:
        with open(out_json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        return rows, [out_json]
    return rows


if __name__ == "__main__":
    fleet_bench(smoke="--smoke" in sys.argv[1:])
