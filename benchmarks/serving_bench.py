"""Continuous serving: bucketed vs per-length prefill, ring vs uniform decode.

Embedded serving (paper Table V) lives on the same bounded-compile budget
as the fed engine: every distinct prompt length that reaches an exact-
length prefill costs an XLA compile, and on an edge device compiles are
seconds while decode steps are milliseconds. This bench drives the
continuous batcher (core/serving.py) over a mixed-length request stream
twice — per-request-length prefill (``min_bucket=0``) vs power-of-two
bucketed prefill — and then compares *decode* modes on an SWA-patterned
model: uniform decode streams the full ``(L, max_slots, max_len)`` cache
every step, ring/bucketed decode reads W-slot ring buffers (SWA layers)
plus a ladder-bucketed K-extent (full-attention layers). Throughput and
compile counts land in ``BENCH_serving.json``.

    PYTHONPATH=src python -m benchmarks.run serving
    PYTHONPATH=src python -m benchmarks.serving_bench --smoke   # CI shapes
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

import jax

from repro.core.serving import ContinuousBatcher
from repro.models import registry
from repro.types import ModelConfig

# decode is dispatch-bound at serving-fleet scale: a reduced-width model
SERVE_CFG = ModelConfig(name="serve-bench-tiny", family="dense",
                        num_layers=2, d_model=64, num_heads=2,
                        num_kv_heads=2, d_ff=128, vocab_size=256)

# gemma3-style local:global pattern at bench width: layer 0 SWA(w=8),
# layer 1 global — exercises both per-layer-kind decode paths
SWA_CFG = ModelConfig(name="serve-bench-swa", family="dense",
                      num_layers=2, d_model=64, num_heads=2,
                      num_kv_heads=2, d_ff=128, vocab_size=256,
                      sliding_window=8, global_every=2)

ARTIFACT = "BENCH_serving.json"


def _stream(rng, vocab: int, lengths) -> list:
    return [rng.integers(0, vocab, int(n), dtype=np.int32) for n in lengths]


def _serve(params, cfg, prompts, *, max_slots, max_len, gen, min_bucket,
           decode_mode="ring", decode_kernel="pallas", warm=False):
    """Serve the stream once; with ``warm=True`` serve it twice and time
    only the second pass — steady-state throughput with every program on
    the ladder already compiled (the decode comparison's honest number;
    the prefill comparison stays cold because compile cost IS its story).
    """
    srv = ContinuousBatcher(params, cfg, max_slots=max_slots,
                            max_len=max_len, min_bucket=min_bucket,
                            decode_mode=decode_mode,
                            decode_kernel=decode_kernel)
    if warm:
        for p in prompts:
            srv.submit(p, max_new=gen)
        srv.run()
        # compile counts stay cumulative (programs ARE shared across
        # passes) but admission stats report the timed pass only
        srv.group_admits, srv.bucket_hist = {}, {}
    for p in prompts:
        srv.submit(p, max_new=gen)
    t0 = time.perf_counter()
    done = srv.run()[-len(prompts):]
    dt = time.perf_counter() - t0
    assert len(done) == len(prompts)
    toks = sum(len(r.out) for r in done)
    return {
        "wall_s": dt,
        "gen_tok_per_s": toks / max(dt, 1e-9),
        "prefill_compiles": srv.prefill_compiles,
        "decode_compiles": srv.decode_compiles,
        "total_compiles": srv.num_compiled,
        "n_buckets": len(srv.buckets),
        "n_decode_buckets": len(srv.decode_buckets),
        "group_admits": {str(k): v for k, v in
                         sorted(srv.group_admits.items())},
        "outputs": [r.out for r in done],
    }


def serving_bench(smoke: bool = False, out_json: str | None = ARTIFACT):
    """Bucketed vs per-length prefill: throughput + compile counts
    (writes BENCH_serving.json)."""
    print("\n== serving bench (bucketed vs per-length prefill) ==")
    cfg = SERVE_CFG
    if smoke:
        max_slots, max_len, gen, n_req = 2, 32, 2, 6
        lengths = [3, 5, 7, 9, 11, 13][:n_req]
    else:
        max_slots, max_len, gen, n_req = 4, 128, 8, 32
        rng_l = np.random.default_rng(1)
        lengths = list(rng_l.integers(1, max_len - gen, n_req))
    rng = np.random.default_rng(0)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    prompts = _stream(rng, cfg.vocab_size, lengths)

    per_len = _serve(params, cfg, prompts, max_slots=max_slots,
                     max_len=max_len, gen=gen, min_bucket=0)
    bucketed = _serve(params, cfg, prompts, max_slots=max_slots,
                      max_len=max_len, gen=gen, min_bucket=8)
    assert bucketed.pop("outputs") == per_len.pop("outputs"), \
        "bucketed prefill changed greedy outputs"
    assert bucketed["prefill_compiles"] <= bucketed["n_buckets"]

    # -- decode: uniform full-cache vs ring/bucketed, on the SWA model --
    # decode-heavy stream (gen >> prompt) so the per-step cache traffic,
    # not prefill, dominates the wall clock
    dec_gen = gen * 3
    dec_lengths = [max(1, n % (max_len - dec_gen)) for n in lengths]
    dec_cfg = SWA_CFG
    dec_params = registry.init_params(jax.random.PRNGKey(1), dec_cfg)
    dec_prompts = _stream(np.random.default_rng(2), dec_cfg.vocab_size,
                          dec_lengths)
    dec_uniform = _serve(dec_params, dec_cfg, dec_prompts,
                         max_slots=max_slots, max_len=max_len, gen=dec_gen,
                         min_bucket=8, decode_mode="uniform", warm=True)
    dec_ring = _serve(dec_params, dec_cfg, dec_prompts,
                      max_slots=max_slots, max_len=max_len, gen=dec_gen,
                      min_bucket=8, decode_mode="ring", warm=True)
    # fused (Pallas) vs einsum-oracle decode kernels on the same ring path
    dec_einsum = _serve(dec_params, dec_cfg, dec_prompts,
                        max_slots=max_slots, max_len=max_len, gen=dec_gen,
                        min_bucket=8, decode_mode="ring",
                        decode_kernel="einsum", warm=True)
    assert dec_einsum.pop("outputs") == dec_ring["outputs"], \
        "fused decode kernels changed greedy outputs"
    assert dec_ring.pop("outputs") == dec_uniform.pop("outputs"), \
        "ring/bucketed decode changed greedy outputs"
    assert dec_uniform["decode_compiles"] == 1
    assert dec_ring["decode_compiles"] <= max(1,
                                              dec_ring["n_decode_buckets"])

    # modeled per-stream HBM bytes for one decode-attend step at the
    # largest K-extent: the quantity the fused kernels exist to cut on TPU
    # (interpret-mode wall clock is not it — see kernel_bench.py)
    from repro.roofline.analysis import attend_decode_bytes
    hd = dec_cfg.d_model // dec_cfg.num_heads
    model_bytes = {
        "n_ctx": max_len,
        "fused": attend_decode_bytes(max_len, dec_cfg.num_kv_heads,
                                     dec_cfg.num_heads, hd),
        "einsum": attend_decode_bytes(max_len, dec_cfg.num_kv_heads,
                                      dec_cfg.num_heads, hd, fused=False),
    }
    model_bytes["fused_over_einsum"] = (model_bytes["fused"]
                                        / model_bytes["einsum"])

    report = {
        "config": {"arch": cfg.name, "max_slots": max_slots,
                   "max_len": max_len, "gen": gen, "requests": n_req,
                   "distinct_prompt_lengths": len(set(map(int, lengths))),
                   "smoke": smoke},
        "per_length": per_len,
        "bucketed": bucketed,
        "prefill_compile_ratio":
            per_len["prefill_compiles"] / max(bucketed["prefill_compiles"],
                                              1),
        "decode": {
            "config": {"arch": dec_cfg.name,
                       "sliding_window": dec_cfg.sliding_window,
                       "gen": dec_gen, "requests": len(dec_prompts)},
            "uniform": dec_uniform,
            "ring": dec_ring,
            "decode_tok_per_s_ratio":
                dec_ring["gen_tok_per_s"]
                / max(dec_uniform["gen_tok_per_s"], 1e-9),
            "fused": {
                "pallas": {k: dec_ring[k] for k in
                           ("wall_s", "gen_tok_per_s", "decode_compiles")},
                "einsum": {k: dec_einsum[k] for k in
                           ("wall_s", "gen_tok_per_s", "decode_compiles")},
                "modeled_attend_bytes_per_stream_step": model_bytes,
            },
        },
    }
    rows = [
        ("serve_per_length", per_len["wall_s"] * 1e6,
         f"{per_len['gen_tok_per_s']:.1f} tok/s "
         f"{per_len['prefill_compiles']} prefill compiles"),
        ("serve_bucketed", bucketed["wall_s"] * 1e6,
         f"{bucketed['gen_tok_per_s']:.1f} tok/s "
         f"{bucketed['prefill_compiles']} prefill compiles "
         f"(<= {bucketed['n_buckets']} buckets)"),
        ("decode_uniform", dec_uniform["wall_s"] * 1e6,
         f"{dec_uniform['gen_tok_per_s']:.1f} tok/s, full "
         f"(L, slots, {max_len}) cache per step"),
        ("decode_ring", dec_ring["wall_s"] * 1e6,
         f"{dec_ring['gen_tok_per_s']:.1f} tok/s, W={dec_cfg.sliding_window}"
         f" rings + K-extent ladder ({dec_ring['decode_compiles']} <= "
         f"{dec_ring['n_decode_buckets']} decode compiles)"),
        ("decode_fused_einsum_oracle", dec_einsum["wall_s"] * 1e6,
         f"{dec_einsum['gen_tok_per_s']:.1f} tok/s einsum oracle; fused "
         f"attend models {model_bytes['fused_over_einsum']:.0%} of its "
         f"HBM bytes/step at n_ctx={max_len}"),
    ]
    for name, us, derived in rows:
        print(f"  {name}: {us / 1e6:.2f}s — {derived}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        return rows, [out_json]
    return rows


if __name__ == "__main__":
    serving_bench(smoke="--smoke" in sys.argv[1:])
