"""Continuous serving: bucketed vs per-length prefill under a mixed stream.

Embedded serving (paper Table V) lives on the same bounded-compile budget
as the fed engine: every distinct prompt length that reaches an exact-
length prefill costs an XLA compile, and on an edge device compiles are
seconds while decode steps are milliseconds. This bench drives the
continuous batcher (core/serving.py) over a mixed-length request stream
twice — per-request-length prefill (``min_bucket=0``) vs power-of-two
bucketed prefill — and writes end-to-end throughput plus *prefill compile
counts* to ``BENCH_serving.json``.

    PYTHONPATH=src python -m benchmarks.run serving
    PYTHONPATH=src python -m benchmarks.serving_bench --smoke   # CI shapes
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

import jax

from repro.core.serving import ContinuousBatcher
from repro.models import registry
from repro.types import ModelConfig

# decode is dispatch-bound at serving-fleet scale: a reduced-width model
SERVE_CFG = ModelConfig(name="serve-bench-tiny", family="dense",
                        num_layers=2, d_model=64, num_heads=2,
                        num_kv_heads=2, d_ff=128, vocab_size=256)

ARTIFACT = "BENCH_serving.json"


def _stream(rng, vocab: int, lengths) -> list:
    return [rng.integers(0, vocab, int(n), dtype=np.int32) for n in lengths]


def _serve(params, cfg, prompts, *, max_slots, max_len, gen, min_bucket):
    srv = ContinuousBatcher(params, cfg, max_slots=max_slots,
                            max_len=max_len, min_bucket=min_bucket)
    for p in prompts:
        srv.submit(p, max_new=gen)
    t0 = time.perf_counter()
    done = srv.run()
    dt = time.perf_counter() - t0
    assert len(done) == len(prompts)
    toks = sum(len(r.out) for r in done)
    return {
        "wall_s": dt,
        "gen_tok_per_s": toks / max(dt, 1e-9),
        "prefill_compiles": srv.prefill_compiles,
        "total_compiles": srv.num_compiled,
        "n_buckets": len(srv.buckets),
        "group_admits": {str(k): v for k, v in
                         sorted(srv.group_admits.items())},
        "outputs": [r.out for r in done],
    }


def serving_bench(smoke: bool = False, out_json: str | None = ARTIFACT):
    """Bucketed vs per-length prefill: throughput + compile counts
    (writes BENCH_serving.json)."""
    print("\n== serving bench (bucketed vs per-length prefill) ==")
    cfg = SERVE_CFG
    if smoke:
        max_slots, max_len, gen, n_req = 2, 32, 2, 6
        lengths = [3, 5, 7, 9, 11, 13][:n_req]
    else:
        max_slots, max_len, gen, n_req = 4, 128, 8, 32
        rng_l = np.random.default_rng(1)
        lengths = list(rng_l.integers(1, max_len - gen, n_req))
    rng = np.random.default_rng(0)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    prompts = _stream(rng, cfg.vocab_size, lengths)

    per_len = _serve(params, cfg, prompts, max_slots=max_slots,
                     max_len=max_len, gen=gen, min_bucket=0)
    bucketed = _serve(params, cfg, prompts, max_slots=max_slots,
                      max_len=max_len, gen=gen, min_bucket=8)
    assert bucketed.pop("outputs") == per_len.pop("outputs"), \
        "bucketed prefill changed greedy outputs"
    assert bucketed["prefill_compiles"] <= bucketed["n_buckets"]

    report = {
        "config": {"arch": cfg.name, "max_slots": max_slots,
                   "max_len": max_len, "gen": gen, "requests": n_req,
                   "distinct_prompt_lengths": len(set(map(int, lengths))),
                   "smoke": smoke},
        "per_length": per_len,
        "bucketed": bucketed,
        "prefill_compile_ratio":
            per_len["prefill_compiles"] / max(bucketed["prefill_compiles"],
                                              1),
    }
    rows = [
        ("serve_per_length", per_len["wall_s"] * 1e6,
         f"{per_len['gen_tok_per_s']:.1f} tok/s "
         f"{per_len['prefill_compiles']} prefill compiles"),
        ("serve_bucketed", bucketed["wall_s"] * 1e6,
         f"{bucketed['gen_tok_per_s']:.1f} tok/s "
         f"{bucketed['prefill_compiles']} prefill compiles "
         f"(<= {bucketed['n_buckets']} buckets)"),
    ]
    for name, us, derived in rows:
        print(f"  {name}: {us / 1e6:.2f}s — {derived}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        return rows, [out_json]
    return rows


if __name__ == "__main__":
    serving_bench(smoke="--smoke" in sys.argv[1:])
